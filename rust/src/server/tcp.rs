//! TCP front-end: accept loop, per-connection reader/writer threads,
//! bounded-queue admission, per-connection protocol negotiation, model
//! routing, and the stats/models/reload control ops.
//!
//! ## Threading model
//!
//! One accept thread; per connection, a **reader** thread that decodes
//! requests and a **writer** thread that emits responses in request
//! order. Score/classify requests are routed through the
//! [`ModelRegistry`] — route resolution is lock-free (the shard table is
//! immutable) and happens **before** admission, so a hot reload of one
//! shard can never stall traffic on another — and admitted to the
//! target [`ModelHub`]'s bounded queue without blocking: if the queue is
//! full the reader immediately enqueues an explicit `overloaded` error
//! instead of buffering — load is shed at the edge, never accumulated.
//! Admitted requests travel to the writer as pending response
//! receivers, bounded by `max_pending_per_conn` (the per-connection
//! pipelining window): a slow consumer backpressures its own reader,
//! not the whole server.
//!
//! ## Protocol negotiation
//!
//! Every connection starts in v1 JSON-lines mode. A
//! `{"op":"hello","proto":N}` request with `N ≥ 2` flips it to the
//! length-prefixed binary framing of [`crate::server::frame`] — the
//! reader switches decoders after answering, and each queued job
//! carries its own rendering instructions, so the in-order response
//! stream stays consistent across the switch. A grant of 3 additionally
//! unlocks the model-routed v3 frame ops (dense score, u32-indexed
//! sparse score, classify); a grant of 4 advertises the online-learning
//! capability (`LEARN_SPARSE` / `LEARN_ACK` — the JSON `learn` op works
//! at any version; like the v3 ops, the grant is capability discovery,
//! not per-frame enforcement); a grant of 5 advertises the runtime
//! shard-lifecycle capability (`add-model` / `remove-model`, below); a
//! grant of 6 advertises batched scoring (`SCORE_BATCH` /
//! `SCORE_BATCH_RESP`, and the JSON `score-batch` twin) — a whole
//! batch costs one queue slot and one worker wakeup, its examples are
//! scored back-to-back by one worker (bit-identical to the same
//! requests sent singly), and each example carries its own status in
//! the response, so one bad example never poisons its batchmates; a
//! grant of 7 advertises the overload-brownout capability — per-request
//! deadlines and admission-lane overrides (`deadline_ms` / `priority`
//! on the JSON ops, the `SCORE_SPARSE_EX` / `SCORE_BATCH_EX` frames on
//! the binary wire), the retryable `deadline-exceeded` shed answered at
//! dequeue, and the `degraded` response flag marking brownout-tier
//! scoring. Clients that never send `hello` (all v1 clients) are served
//! exactly as before, on the default shard.
//!
//! ## Online learning
//!
//! A `learn` request (JSON op or `LEARN_SPARSE` frame) routes a labeled
//! example through the registry to the target shard's
//! [`OnlineTrainer`](crate::coordinator::online::OnlineTrainer) — a
//! non-blocking `try_send` onto the trainer's bounded queue, so the
//! wire path never waits on learning: a full queue sheds the example
//! with an explicit retryable `overloaded` error, exactly like score
//! admission. The ack carries the shard's current serving generation
//! and the trainer's cumulative accepted-example count, so clients can
//! watch snapshot publishes land without a second channel.
//!
//! ## Control ops
//!
//! `stats` returns the aggregated [`StatsReport`] (throughput,
//! features-touched percentiles, early-exit rate, shed counts, plus
//! per-wire-class and per-shard splits); `models` lists the shard
//! table with each shard's lifecycle state; `reload` hot-swaps one
//! shard's serving model with zero downtime (see [`ModelHub`]); the v5
//! `add-model` / `remove-model` ops register and retire whole shards at
//! runtime via the registry's epoch-based route swap, so churn on one
//! shard never stalls traffic on its siblings. All arrive over the same
//! wire as ordinary requests — in binary mode they ride inside
//! `JSON_REQ`/`JSON_RESP` envelope frames — so any connection can act
//! as a control channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{IoBackend, ServerConfig, TrainerWireConfig};
use crate::coordinator::online::SnapshotStore;
use crate::coordinator::service::{
    CompletionNotifier, Features, Lane, ModelSnapshot, ReqKind, ScoreResponse, ServingModel,
    SubmitOpts,
};
use crate::error::{Error, Result};
use crate::server::bufpool::BufPool;
use crate::server::faultpoint;
use crate::server::frame::{
    self, ErrorCode, Frame, FrameError, FrameRef,
};
use crate::server::hub::{HubError, ModelHub};
use crate::server::protocol::{
    BatchRow, ModelEntry, ModelStatsReport, Request, Response, StatsReport, WireStats, PROTO_V2,
    PROTO_V7,
};
use crate::server::registry::{ModelRegistry, RegistryError, DEFAULT_MODEL};

/// Which wire class a response is rendered on — the key of the
/// per-protocol stats split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WireClass {
    /// v1 JSON line.
    V1,
    /// JSON document inside a v2+ envelope frame.
    V2Json,
    /// Native v2+ binary frame.
    V2Binary,
}

/// Served/bytes counters for one wire class.
#[derive(Default)]
pub(crate) struct WireCounters {
    pub(crate) served: AtomicU64,
    pub(crate) bytes: AtomicU64,
}

impl WireCounters {
    fn snapshot(&self) -> WireStats {
        WireStats {
            served: self.served.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

/// Server-wide shared state (shared by both transport backends; the
/// thread-backend-only fields are simply idle under the event loop).
pub(crate) struct Shared {
    pub(crate) registry: ModelRegistry,
    /// The server's trainer knobs (`--learn ...`), reused when a v5
    /// `add-model` asks for a trainer on the new shard; `None` means
    /// learn-enabled adds are rejected.
    pub(crate) trainer: Option<TrainerWireConfig>,
    pub(crate) shutting_down: AtomicBool,
    pub(crate) accepted: AtomicU64,
    pub(crate) overloaded: AtomicU64,
    pub(crate) protocol_errors: AtomicU64,
    started: Instant,
    /// Stream clones used to unblock connection readers at shutdown,
    /// keyed by connection id; entries are removed when the connection
    /// closes so long-lived servers don't leak fds. (Thread backend
    /// only — the event loop owns its connections outright.)
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    conn_joins: Mutex<Vec<JoinHandle<()>>>,
    pub(crate) max_pending: usize,
    pub(crate) max_frame_bytes: usize,
    pub(crate) max_nnz: usize,
    /// Per-request example cap for `SCORE_BATCH` / `score-batch`
    /// (advertised to v6 clients; an over-long batch is one whole-batch
    /// error, not a truncation).
    pub(crate) max_batch_examples: usize,
    /// Concurrent-connection admission cap (both backends).
    pub(crate) max_conns: usize,
    /// Write deadline per connection, ms (0 = wait forever): a peer
    /// that stops reading its responses is cut loose instead of
    /// parking a writer thread (or event-loop buffer) indefinitely.
    pub(crate) write_timeout_ms: u64,
    /// Idle deadline per connection, ms (0 = never): a peer that goes
    /// silent — including a slowloris trickling one byte per minute —
    /// is reaped once nothing arrives for this long.
    pub(crate) idle_timeout_ms: u64,
    /// Batches refused by the *adaptive* admission cap (queue under
    /// pressure; retryable) — distinct from `overloaded`, which counts
    /// whole-queue sheds, and from the fixed `max_batch_examples`
    /// ceiling, which is a non-retryable protocol error.
    pub(crate) batch_shed: AtomicU64,
    /// Live connections right now (for the `max_conns` screen).
    pub(crate) live_conns: AtomicU64,
    /// Default request deadline, ms (0 = none): applied to every
    /// score/classify/batch admission whose request carries no explicit
    /// `deadline_ms`, so operators can bound queue-wait latency without
    /// touching clients.
    pub(crate) deadline_default_ms: u64,
    /// Per-wire-class served/bytes (indexed v1, v2-json, v2-binary).
    wire: [WireCounters; 3],
    /// Recycled transport buffers (connection read/write/deferred
    /// buffers in the event loop, response scratch in the writer
    /// threads).
    pub(crate) pool: BufPool,
}

impl Shared {
    pub(crate) fn wire(&self, class: WireClass) -> &WireCounters {
        &self.wire[class as usize]
    }
}

/// Join handles of whichever transport backend is running.
enum BackendHandles {
    /// Thread-per-connection backend: the accept loop's handle
    /// (connection threads are tracked in [`Shared::conn_joins`]).
    Threads(JoinHandle<()>),
    /// Sharded epoll event loop (Linux only).
    #[cfg(target_os = "linux")]
    Event(crate::server::event_loop::EventBackend),
}

/// A running TCP serving front-end.
///
/// Dropping the server shuts it down cleanly (stops accepting, closes
/// connections, drains every admitted request, joins all threads).
pub struct TcpServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    backend: Option<BackendHandles>,
}

impl TcpServer {
    /// Bind `cfg.listen` and start serving `snapshot` as the single
    /// (default) shard — the original single-model entry point, kept
    /// for drop-in compatibility.
    pub fn serve(cfg: &ServerConfig, snapshot: ModelSnapshot) -> Result<TcpServer> {
        Self::serve_models(cfg, vec![(DEFAULT_MODEL.to_string(), snapshot.into())])
    }

    /// Bind `cfg.listen` and serve a registry of named model shards
    /// behind the one port. The first entry is the default shard (wire
    /// model id 0): it answers every request that does not name a
    /// model, so v1 single-model clients work unmodified.
    pub fn serve_models(
        cfg: &ServerConfig,
        models: Vec<(String, ServingModel)>,
    ) -> Result<TcpServer> {
        cfg.validate()?;
        if let Some(spec) = faultpoint::init_from_env() {
            eprintln!("fault injection armed: {spec}");
        }
        // Event backend: the wake eventfds must exist before the
        // registry so every hub's completion notifier can signal them
        // from its first spawned worker generation.
        let (notifier, wake_fds) = match cfg.io_backend {
            IoBackend::EventLoop => make_event_wakeups(cfg.event_threads)?,
            IoBackend::Threads => (CompletionNotifier::default(), Vec::new()),
        };
        let registry = ModelRegistry::new_with_opts(
            models,
            cfg.max_batch,
            cfg.queue,
            cfg.workers,
            cfg.seed,
            notifier,
            cfg.brownout.clone(),
        )?;
        if let Some(dir) = &cfg.snapshot_dir {
            // Startup recovery: warm every binary shard from its newest
            // *valid* on-disk generation before any trainer attaches, so
            // the trainer's warm start resumes exactly where the last
            // published generation left off. Torn or corrupt files are
            // skipped inside the store (checksummed header); a shard
            // with no usable snapshot just serves its boot model.
            for info in registry.infos() {
                if info.hub.kind != "binary" {
                    continue;
                }
                let store = match SnapshotStore::open(dir.join(&info.name)) {
                    Ok(store) => store,
                    Err(e) => {
                        eprintln!(
                            "warning: snapshot dir for shard {:?} unavailable ({e})",
                            info.name
                        );
                        continue;
                    }
                };
                if let Some((gen, snap)) = store.load_newest() {
                    match registry.reload(Some(&info.name), snap.into()) {
                        Ok(_) => eprintln!(
                            "recovered shard {:?} from snapshot generation {gen}",
                            info.name
                        ),
                        Err(e) => eprintln!(
                            "warning: shard {:?} snapshot generation {gen} not loadable: {e}",
                            info.name
                        ),
                    }
                }
            }
            // From here on, every attached trainer persists its
            // publishes under `<dir>/<shard-name>/`.
            registry.set_snapshot_root(dir.clone());
        }
        if let Some(trainer_cfg) = &cfg.trainer {
            // Online learning: attach a trainer to every binary shard.
            // Ensemble shards stay read-only — their 1-vs-1 voters are
            // trained upstream and arrive whole via `reload`.
            let names: Vec<String> = registry
                .infos()
                .into_iter()
                .filter(|info| info.hub.kind == "binary")
                .map(|info| info.name)
                .collect();
            for name in &names {
                registry.attach_trainer(Some(name.as_str()), trainer_cfg)?;
            }
        }
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| Error::io(&cfg.listen, e))?;
        let local_addr = listener.local_addr().map_err(|e| Error::io(&cfg.listen, e))?;
        let shared = Arc::new(Shared {
            registry,
            trainer: cfg.trainer.clone(),
            shutting_down: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            started: Instant::now(),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            conn_joins: Mutex::new(Vec::new()),
            max_pending: cfg.max_pending_per_conn,
            max_frame_bytes: cfg.max_frame_bytes,
            max_nnz: cfg.max_nnz,
            max_batch_examples: cfg.max_batch_examples,
            max_conns: cfg.max_conns,
            write_timeout_ms: cfg.write_timeout_ms,
            idle_timeout_ms: cfg.idle_timeout_ms,
            batch_shed: AtomicU64::new(0),
            live_conns: AtomicU64::new(0),
            deadline_default_ms: cfg.deadline_default_ms,
            wire: Default::default(),
            pool: BufPool::serving_default(),
        });
        let backend = match cfg.io_backend {
            IoBackend::Threads => {
                let accept_shared = shared.clone();
                BackendHandles::Threads(std::thread::spawn(move || {
                    accept_loop(listener, accept_shared)
                }))
            }
            IoBackend::EventLoop => {
                spawn_event_backend(listener, shared.clone(), cfg.event_threads, wake_fds)?
            }
        };
        Ok(TcpServer { shared, local_addr, backend: Some(backend) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Current server statistics (same payload as the `stats` op).
    pub fn stats(&self) -> StatsReport {
        report(&self.shared)
    }

    /// Programmatic hot reload of the default shard (same semantics as
    /// an un-routed `reload` op).
    pub fn reload(
        &self,
        model: impl Into<ServingModel>,
    ) -> std::result::Result<usize, HubError> {
        self.shared.registry.default_hub().reload(model)
    }

    /// Programmatic hot reload of a named shard (same semantics as a
    /// routed `reload` op).
    pub fn reload_model(
        &self,
        name: &str,
        model: impl Into<ServingModel>,
    ) -> std::result::Result<usize, RegistryError> {
        self.shared.registry.reload(Some(name), model.into())
    }

    /// The registry's shard table (same payload as the `models` op).
    pub fn models(&self) -> Vec<ModelEntry> {
        model_entries(&self.shared)
    }

    /// Block on the accept loop. It only exits if the listener itself
    /// fails (in normal operation the process runs until killed — there
    /// is no cross-thread stop signal once `self` is consumed; use
    /// [`Self::shutdown`] instead of `wait` when you need a programmatic
    /// stop). Cleans up if the loop ever does exit.
    pub fn wait(mut self) {
        match self.backend.take() {
            Some(BackendHandles::Threads(join)) => {
                let _ = join.join();
                self.teardown_connections();
            }
            #[cfg(target_os = "linux")]
            Some(BackendHandles::Event(backend)) => {
                // The loops only exit once the flag is raised, which the
                // accept loop's failure path also sets.
                backend.join();
            }
            None => {}
        }
        self.shared.registry.shutdown();
    }

    /// Stop accepting, drain and answer every admitted request, join all
    /// threads, and return the final statistics.
    pub fn shutdown(mut self) -> StatsReport {
        self.shutdown_impl();
        report(&self.shared)
    }

    fn shutdown_impl(&mut self) {
        let Some(backend) = self.backend.take() else {
            return; // already shut down
        };
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so it observes the flag.
        let _ = TcpStream::connect(self.local_addr);
        match backend {
            BackendHandles::Threads(accept_join) => {
                let _ = accept_join.join();
                self.teardown_connections();
            }
            #[cfg(target_os = "linux")]
            BackendHandles::Event(backend) => backend.join(),
        }
        self.shared.registry.shutdown();
    }

    fn teardown_connections(&self) {
        // Unblock every connection reader; EOF ends the reader, which
        // drops the job channel, which lets the writer drain and exit.
        for (_, stream) in self.shared.conns.lock().unwrap().drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let joins = std::mem::take(&mut *self.shared.conn_joins.lock().unwrap());
        for join in joins {
            let _ = join.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Create the event backend's worker-completion wakeups: one eventfd
/// per loop shard, plus the [`CompletionNotifier`] the coordinator
/// workers fire to signal them all (Linux only).
#[cfg(target_os = "linux")]
fn make_event_wakeups(
    event_threads: usize,
) -> Result<(CompletionNotifier, Vec<Arc<crate::server::event_loop::WakeFd>>)> {
    let mut fds = Vec::with_capacity(event_threads.max(1));
    for _ in 0..event_threads.max(1) {
        fds.push(Arc::new(crate::server::event_loop::WakeFd::new()?));
    }
    let signal = fds.clone();
    let notifier = CompletionNotifier::new(move || {
        for fd in &signal {
            fd.signal();
        }
    });
    Ok((notifier, fds))
}

#[cfg(not(target_os = "linux"))]
fn make_event_wakeups(_event_threads: usize) -> Result<(CompletionNotifier, Vec<()>)> {
    Ok((CompletionNotifier::default(), Vec::new()))
}

/// Start the epoll backend (Linux). `ServerConfig::validate` already
/// rejects the event loop elsewhere; the stub keeps non-Linux builds
/// honest if a caller skips validation.
#[cfg(target_os = "linux")]
fn spawn_event_backend(
    listener: TcpListener,
    shared: Arc<Shared>,
    event_threads: usize,
    wake_fds: Vec<Arc<crate::server::event_loop::WakeFd>>,
) -> Result<BackendHandles> {
    Ok(BackendHandles::Event(crate::server::event_loop::spawn(
        listener,
        shared,
        event_threads,
        wake_fds,
    )?))
}

#[cfg(not(target_os = "linux"))]
fn spawn_event_backend(
    _listener: TcpListener,
    _shared: Arc<Shared>,
    _event_threads: usize,
    _wake_fds: Vec<()>,
) -> Result<BackendHandles> {
    Err(Error::Config("io_backend event-loop needs epoll (Linux); use threads here".into()))
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        // Admission cap: accept-and-close instead of letting the kernel
        // backlog fill silently — the refused peer sees an immediate
        // EOF it can back off on.
        if shared.live_conns.load(Ordering::Relaxed) >= shared.max_conns as u64 {
            drop(stream);
            continue;
        }
        shared.accepted.fetch_add(1, Ordering::Relaxed);
        shared.live_conns.fetch_add(1, Ordering::Relaxed);
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(conn_id, clone);
        }
        let conn_shared = shared.clone();
        let join = std::thread::spawn(move || {
            handle_conn(stream, &conn_shared);
            // Release this connection's shutdown clone (fd) as soon as
            // the connection ends, not at server teardown.
            conn_shared.conns.lock().unwrap().remove(&conn_id);
            conn_shared.live_conns.fetch_sub(1, Ordering::Relaxed);
        });
        let mut joins = shared.conn_joins.lock().unwrap();
        // Reap handles of connections that already finished so a
        // long-running server doesn't accumulate one per connection.
        joins.retain(|j| !j.is_finished());
        joins.push(join);
    }
}

/// How a pending score/classify response must be rendered — decided at
/// admission time, so the writer needs no codec state of its own and
/// the v1→v2 switch stays consistent across the in-order job stream.
pub(crate) enum Wire {
    /// v1 JSON line, echoing the optional request id.
    V1 { id: Option<u64> },
    /// v2+ binary `SCORE`/`CLASS`/`ERROR` frame, stamped with the
    /// serving generation captured at admission (classify pendings
    /// render as `CLASS`, score pendings as `SCORE`). `ex` marks a v7
    /// EX request, whose score renders as `SCORE_EX` /
    /// `SCORE_BATCH_RESP_EX` so the `degraded` flag survives the wire
    /// (legacy frames have nowhere to carry it).
    V2Binary { gen: u32, ex: bool },
    /// v2+ `JSON_RESP` envelope frame (a JSON-op request on a binary
    /// connection, e.g. a dense score through the envelope).
    V2Json { id: Option<u64> },
}

impl Wire {
    pub(crate) fn class(&self) -> WireClass {
        match self {
            Wire::V1 { .. } => WireClass::V1,
            Wire::V2Json { .. } => WireClass::V2Json,
            Wire::V2Binary { .. } => WireClass::V2Binary,
        }
    }
}

/// Per-example admission verdict inside a batch, recorded in request
/// order at decode time so the writer can merge worker results with
/// screen-time rejections without any index bookkeeping: a `Submitted`
/// slot consumes the next in-order worker result, a `Rejected` slot
/// renders its stored error.
pub(crate) enum BatchSlot {
    /// Screened clean and admitted with the batch.
    Submitted,
    /// Rejected at screen time (nnz cap, unsorted support, non-finite
    /// value); never reached a worker. Its batchmates are unaffected.
    Rejected { code: ErrorCode, msg: String },
}

/// What the reader hands the writer, in request order.
pub(crate) enum Job {
    /// Fully-encoded response bytes (a JSON line or a binary frame),
    /// tagged with the wire class for the byte counters.
    Bytes(Vec<u8>, WireClass),
    /// An admitted score/classify request whose response is still being
    /// computed.
    Pending { wire: Wire, rx: Receiver<ScoreResponse> },
    /// An admitted `SCORE_BATCH` / `score-batch` whose responses are
    /// still being computed: one receiver for the whole batch (its
    /// examples are scored back-to-back by one worker), plus the
    /// decode-time slot verdicts the writer merges into one response.
    PendingBatch { wire: Wire, rx: Receiver<Vec<ScoreResponse>>, slots: Vec<BatchSlot> },
}

/// Reader-side verdict for one decoded request.
pub(crate) enum Step {
    /// Enqueue this job and keep reading.
    Job(Job),
    /// Enqueue, then switch the connection to binary framing.
    JobThenBinary(Job),
    /// Enqueue, then close the connection (unrecoverable stream state).
    JobThenClose(Job),
    /// Close immediately.
    Close,
}

fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    // Deadlines, set before the clone so both halves share them: a peer
    // that stops reading its responses hits the write timeout, one that
    // goes silent (slowloris included — the timeout is per read call,
    // so trickled bytes only buy one more window each) hits the read
    // timeout. Either way the connection closes; admitted requests are
    // still drained and answered by the writer before it exits.
    if shared.write_timeout_ms > 0 {
        let _ = stream
            .set_write_timeout(Some(std::time::Duration::from_millis(shared.write_timeout_ms)));
    }
    if shared.idle_timeout_ms > 0 {
        let _ = stream
            .set_read_timeout(Some(std::time::Duration::from_millis(shared.idle_timeout_ms)));
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let (jtx, jrx) = sync_channel::<Job>(shared.max_pending);
    let writer_shared = shared.clone();
    let writer = std::thread::spawn(move || writer_loop(stream, jrx, &writer_shared));

    let mut binary = false;
    let mut line = String::new();
    // One body buffer for the whole connection: at steady state the
    // binary read path touches no allocator.
    let mut body = shared.pool.get();
    loop {
        let step = if binary {
            match Frame::read_body(&mut reader, &mut body, shared.max_frame_bytes) {
                Ok(()) => frame_step(&body, shared),
                Err(FrameError::Eof) => Step::Close,
                Err(e) => {
                    // Framing is lost — a byte stream cannot resync
                    // after a bad prefix. Report once, then close.
                    shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    Step::JobThenClose(Job::Bytes(
                        Frame::Error {
                            code: ErrorCode::BadFrame,
                            retryable: false,
                            msg: e.to_string(),
                        }
                        .encode(),
                        WireClass::V2Binary,
                    ))
                }
            }
        } else {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => Step::Close,
                Ok(_) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    json_step(trimmed, shared)
                }
            }
        };
        match step {
            Step::Job(job) => {
                if jtx.send(job).is_err() {
                    break; // writer gone (connection dead)
                }
            }
            Step::JobThenBinary(job) => {
                if jtx.send(job).is_err() {
                    break;
                }
                binary = true;
            }
            Step::JobThenClose(job) => {
                let _ = jtx.send(job);
                break;
            }
            Step::Close => break,
        }
    }
    shared.pool.put(body);
    drop(jtx); // writer drains the remaining jobs, then exits
    let _ = writer.join();
}

/// Handle one v1 JSON line.
pub(crate) fn json_step(line: &str, shared: &Shared) -> Step {
    match Request::parse(line) {
        Err(e) => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(Job::Bytes(
                Response::Error { id: None, error: e, retryable: false }.to_line().into_bytes(),
                WireClass::V1,
            ))
        }
        Ok(Request::Hello { proto }) => {
            // Grant the highest version both sides speak; v1 keeps the
            // connection on JSON lines (transparent fallback).
            let granted = proto.min(PROTO_V7).max(1);
            // One snapshot: (gen, dim) must not tear across a reload.
            // The handshake advertises the default shard, which is what
            // single-model clients will be talking to.
            let (gen, dim) = shared.registry.default_hub().serving_info();
            let resp = Response::Hello { proto: granted, gen, dim };
            let job = Job::Bytes(resp.to_line().into_bytes(), WireClass::V1);
            if granted >= PROTO_V2 {
                Step::JobThenBinary(job)
            } else {
                Step::Job(job)
            }
        }
        Ok(req) => json_request_step(req, shared, /* enveloped= */ false),
    }
}

/// Resolve a request's admission options: an explicit `deadline_ms`
/// wins over the server default (`--deadline-default-ms`), and 0
/// disables. The `Instant::now()` read is skipped entirely when no
/// deadline applies, so the common no-deadline path stays free. The
/// lane override passes through untouched (`None` = the op default:
/// singles → interactive, batches → bulk).
pub(crate) fn admission_opts(
    shared: &Shared,
    deadline_ms: Option<u64>,
    lane: Option<Lane>,
) -> SubmitOpts {
    let ms = deadline_ms.unwrap_or(shared.deadline_default_ms);
    SubmitOpts {
        deadline: (ms > 0).then(|| Instant::now() + std::time::Duration::from_millis(ms)),
        lane,
    }
}

/// Map a v7 EX frame's admission fields onto [`admission_opts`] inputs:
/// a zero deadline means "unset" (the server default applies), and the
/// lane byte was already range-checked at decode.
pub(crate) fn ex_admission(deadline_ms: u32, lane: u8) -> (Option<u64>, Option<Lane>) {
    let deadline = (deadline_ms > 0).then_some(deadline_ms as u64);
    let lane = match lane {
        frame::LANE_INTERACTIVE => Some(Lane::Interactive),
        frame::LANE_BULK => Some(Lane::Bulk),
        _ => None,
    };
    (deadline, lane)
}

/// Handle a JSON-op request arriving either as a bare v1 line
/// (`enveloped = false`) or inside a v2 `JSON_REQ` frame (`true`); the
/// response rides the matching vehicle.
pub(crate) fn json_request_step(req: Request, shared: &Shared, enveloped: bool) -> Step {
    let class = if enveloped { WireClass::V2Json } else { WireClass::V1 };
    let render = |resp: Response| -> Job {
        if enveloped {
            Job::Bytes(Frame::JsonResp(resp.to_json().to_string_compact()).encode(), class)
        } else {
            Job::Bytes(resp.to_line().into_bytes(), class)
        }
    };
    match req {
        Request::Hello { .. } => {
            // Renegotiation inside a binary connection is not a thing;
            // as a bare v1 line it is handled by `json_step`.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(render(Response::Error {
                id: None,
                error: "hello: already negotiated".into(),
                retryable: false,
            }))
        }
        Request::Ping => Step::Job(render(Response::Pong)),
        Request::Stats => Step::Job(render(Response::Stats(report(shared)))),
        Request::Models => Step::Job(render(Response::Models(model_entries(shared)))),
        Request::Reload { model, snapshot } => {
            match shared.registry.reload(model.as_deref(), snapshot) {
                Ok(dim) => Step::Job(render(Response::Reloaded { dim })),
                Err(e) => Step::Job(render(Response::Error {
                    id: None,
                    error: e.to_string(),
                    retryable: false,
                })),
            }
        }
        Request::AddModel { name, snapshot, learn } => {
            // Trainer attach reuses the server's own `--learn` knobs so a
            // runtime shard behaves exactly like a boot-time one; without
            // them there is nothing sane to attach.
            let trainer = match (learn, &shared.trainer) {
                (false, _) => None,
                (true, Some(cfg)) => Some(cfg),
                (true, None) => {
                    return Step::Job(render(Response::Error {
                        id: None,
                        error: "add-model: server has no trainer configured (--learn)".into(),
                        retryable: false,
                    }))
                }
            };
            match shared.registry.add_model(&name, snapshot, trainer) {
                Ok((id, dim)) => Step::Job(render(Response::Added { name, id, dim })),
                Err(e) => Step::Job(render(Response::Error {
                    id: None,
                    error: e.to_string(),
                    retryable: matches!(e, RegistryError::ModelBusy(_)),
                })),
            }
        }
        Request::RemoveModel { name } => match shared.registry.remove_model(&name) {
            Ok(()) => Step::Job(render(Response::Removed { name })),
            Err(e) => Step::Job(render(Response::Error {
                id: None,
                error: e.to_string(),
                retryable: matches!(e, RegistryError::ModelBusy(_)),
            })),
        },
        Request::Learn { id, model, label, features } => {
            // Learning cost scales with the support too: the same nnz
            // knob screens learn payloads on every wire.
            if matches!(features, Features::Sparse { .. }) && features.nnz() > shared.max_nnz {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return Step::Job(render(Response::Error {
                    id,
                    error: format!(
                        "nnz {} exceeds server cap {}",
                        features.nnz(),
                        shared.max_nnz
                    ),
                    retryable: false,
                }));
            }
            match shared.registry.learn(model.as_deref(), features, label as f64) {
                Ok((gen, seen)) => Step::Job(render(Response::Learned { id, gen, seen })),
                Err(RegistryError::LearnShed) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    Step::Job(render(Response::Error {
                        id,
                        error: "overloaded".into(),
                        retryable: true,
                    }))
                }
                Err(e) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: false,
                })),
            }
        }
        Request::ScoreBatch { id, model, examples, deadline_ms, priority } => {
            if examples.len() > shared.max_batch_examples {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return Step::Job(render(Response::Error {
                    id,
                    error: format!(
                        "batch count {} exceeds server cap {}",
                        examples.len(),
                        shared.max_batch_examples
                    ),
                    retryable: false,
                }));
            }
            let hub = match shared.registry.resolve_name(model.as_deref()) {
                Ok((_, hub)) => hub,
                Err(e) => {
                    return Step::Job(render(Response::Error {
                        id,
                        error: e.to_string(),
                        retryable: false,
                    }))
                }
            };
            let cap = effective_batch_cap(shared, &hub);
            if examples.len() > cap {
                shared.batch_shed.fetch_add(1, Ordering::Relaxed);
                return Step::Job(render(Response::Error {
                    id,
                    error: format!(
                        "batch count {} exceeds adaptive cap {cap} (queue under pressure); \
                         retry with a smaller batch",
                        examples.len()
                    ),
                    retryable: true,
                }));
            }
            // Per-example screens fill a `Rejected` slot instead of
            // failing the batch: only clean examples travel to the
            // worker, and the writer merges the verdicts back in order.
            let mut slots = Vec::with_capacity(examples.len());
            let mut clean = Vec::with_capacity(examples.len());
            for features in examples {
                if matches!(features, Features::Sparse { .. })
                    && features.nnz() > shared.max_nnz
                {
                    shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    slots.push(BatchSlot::Rejected {
                        code: ErrorCode::BadRequest,
                        msg: format!(
                            "nnz {} exceeds server cap {}",
                            features.nnz(),
                            shared.max_nnz
                        ),
                    });
                    continue;
                }
                match features.validate() {
                    Err(e) => {
                        shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                        let code = if e.contains("non-finite") {
                            ErrorCode::NonFinite
                        } else {
                            ErrorCode::BadRequest
                        };
                        slots.push(BatchSlot::Rejected { code, msg: e });
                    }
                    Ok(()) => {
                        clean.push(features);
                        slots.push(BatchSlot::Submitted);
                    }
                }
            }
            // Admit even an all-rejected batch: the empty submit keeps
            // the one-queue-slot accounting and response ordering
            // uniform, and the worker answers it with an empty vec.
            match hub.submit_batch_opts(clean, 0, admission_opts(shared, deadline_ms, priority))
            {
                Ok((rx, _)) => {
                    let wire = if enveloped { Wire::V2Json { id } } else { Wire::V1 { id } };
                    Step::Job(Job::PendingBatch { wire, rx, slots })
                }
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    Step::Job(render(Response::Error {
                        id,
                        error: "overloaded".into(),
                        retryable: true,
                    }))
                }
                Err(e @ HubError::Closed) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: true,
                })),
                Err(
                    e @ (HubError::DimMismatch { .. }
                    | HubError::StaleGeneration { .. }
                    | HubError::WrongKind { .. }),
                ) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: false,
                })),
            }
        }
        Request::Score { .. } | Request::Classify { .. } => {
            let (id, model, features, kind, deadline_ms, priority) = match req {
                Request::Score { id, model, features, deadline_ms, priority } => {
                    (id, model, features, ReqKind::Score, deadline_ms, priority)
                }
                Request::Classify { id, model, features, verbose, deadline_ms, priority } => {
                    let kind =
                        if verbose { ReqKind::ClassifyVerbose } else { ReqKind::Classify };
                    (id, model, features, kind, deadline_ms, priority)
                }
                _ => unreachable!("outer arm admits only score/classify"),
            };
            // The nnz knob bounds per-request compute on every wire, not
            // just the binary one — a classify amplifies each coordinate
            // by C(C-1)/2 voters, so an uncapped JSON support would
            // bypass the operator's limit entirely.
            if matches!(features, Features::Sparse { .. }) && features.nnz() > shared.max_nnz {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return Step::Job(render(Response::Error {
                    id,
                    error: format!(
                        "nnz {} exceeds server cap {}",
                        features.nnz(),
                        shared.max_nnz
                    ),
                    retryable: false,
                }));
            }
            // Resolve the route before admission: an unknown model is a
            // clean structured error, and a valid one hands us the
            // shard's hub without any registry-wide locking.
            let hub = match shared.registry.resolve_name(model.as_deref()) {
                Ok((_, hub)) => hub,
                Err(e) => {
                    return Step::Job(render(Response::Error {
                        id,
                        error: e.to_string(),
                        retryable: false,
                    }))
                }
            };
            match hub.submit_pinned_opts(
                features,
                0,
                kind,
                admission_opts(shared, deadline_ms, priority),
            ) {
                Ok((rx, _)) => {
                    let wire = if enveloped { Wire::V2Json { id } } else { Wire::V1 { id } };
                    Step::Job(Job::Pending { wire, rx })
                }
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    Step::Job(render(Response::Error {
                        id,
                        error: "overloaded".into(),
                        retryable: true,
                    }))
                }
                // StaleGeneration cannot happen on an unpinned submit;
                // fold it with the other non-retryable rejections for
                // exhaustiveness.
                Err(
                    e @ (HubError::DimMismatch { .. }
                    | HubError::StaleGeneration { .. }
                    | HubError::WrongKind { .. }),
                ) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: false,
                })),
                // Closed is a race with this shard's retirement (or with
                // the whole server's shutdown, where the connection dies
                // momentarily anyway): a structured retryable error keeps
                // the connection usable for its other routes.
                Err(e @ HubError::Closed) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: true,
                })),
            }
        }
    }
}

/// Adaptive `SCORE_BATCH` / `score-batch` admission cap: the
/// configured `max_batch_examples` ceiling scaled by the target
/// shard's free queue capacity, never below 1. An empty queue admits
/// the full ceiling; a deep queue admits only small batches, shedding
/// the rest with a *retryable* error (counted in `batch_shed`) — one
/// giant batch cannot monopolize a worker while singles are already
/// queueing behind it. The depth read is racy by design: it is a
/// pressure heuristic, and [`crate::server::hub::ModelHub::queue_load`]
/// over-approximates, so the cap only ever errs toward shedding.
fn effective_batch_cap(shared: &Shared, hub: &ModelHub) -> usize {
    let (depth, capacity) = hub.queue_load();
    if capacity == 0 {
        return shared.max_batch_examples;
    }
    let free = capacity - depth;
    (shared.max_batch_examples * free / capacity).max(1)
}

/// Handle one v2/v3 binary frame *body*, decoded zero-copy: sparse
/// payloads are screened (nnz cap, sorted support, finiteness) as raw
/// byte slices, and owned [`Features`] are only materialized for
/// requests that are actually going to be admitted. Shared by both
/// transport backends, so the wire semantics cannot drift between
/// them.
pub(crate) fn frame_step(body: &[u8], shared: &Shared) -> Step {
    let frame = match FrameRef::decode_borrowed(body) {
        Ok(frame) => frame,
        Err(e) => {
            // Framing is lost — a byte stream cannot resync after a bad
            // layout. Report once, then close.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return Step::JobThenClose(Job::Bytes(
                Frame::Error {
                    code: ErrorCode::BadFrame,
                    retryable: false,
                    msg: e.to_string(),
                }
                .encode(),
                WireClass::V2Binary,
            ));
        }
    };
    let err = |code: ErrorCode, msg: String| -> Step {
        Step::Job(Job::Bytes(
            Frame::Error { code, retryable: code.retryable(), msg }.encode(),
            WireClass::V2Binary,
        ))
    };
    // In-place structural screen for a sparse payload: the nnz knob
    // caps per-request compute, then sortedness/finiteness are checked
    // against the raw pair bytes — nothing allocated for a rejected
    // request. `Ok(())` clears the payload for admission.
    let screen = |nnz: usize, check: Result<(), &'static str>| -> Result<(), Step> {
        if nnz > shared.max_nnz {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return Err(err(
                ErrorCode::BadRequest,
                format!("nnz {nnz} exceeds server cap {}", shared.max_nnz),
            ));
        }
        if let Err(e) = check {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let code = if e.contains("non-finite") {
                ErrorCode::NonFinite
            } else {
                ErrorCode::BadRequest
            };
            return Err(err(code, e.to_string()));
        }
        Ok(())
    };
    // Route and admit one screened payload. The pin check, admission,
    // and generation stamp all happen under one hub critical section:
    // the stamped generation is the one whose workers answer, even
    // across a racing reload. `opts` carries the v7 admission fields
    // (legacy ops pass the server defaults); `ex` picks the response
    // framing.
    let admit = |model: u16, gen: u32, features: Features, kind: ReqKind, opts: SubmitOpts,
                 ex: bool|
     -> Step {
        // Route resolution is lock-free and happens before admission: a
        // reload of another shard can never delay this request.
        let hub = match shared.registry.resolve_id(model) {
            Ok(hub) => hub,
            Err(e) => return err(ErrorCode::UnknownModel, e.to_string()),
        };
        match hub.submit_pinned_opts(features, gen, kind, opts) {
            Ok((rx, serving)) => {
                Step::Job(Job::Pending { wire: Wire::V2Binary { gen: serving, ex }, rx })
            }
            Err(e @ HubError::StaleGeneration { .. }) => {
                err(ErrorCode::StaleGeneration, e.to_string())
            }
            Err(HubError::Overloaded) => {
                shared.overloaded.fetch_add(1, Ordering::Relaxed);
                err(ErrorCode::Overloaded, "overloaded".into())
            }
            Err(e @ HubError::DimMismatch { .. }) => err(ErrorCode::DimMismatch, e.to_string()),
            Err(e @ HubError::WrongKind { .. }) => err(ErrorCode::WrongModel, e.to_string()),
            // A shard mid-retirement (or server shutdown) answers like a
            // dead worker generation: retryable, connection intact.
            Err(e @ HubError::Closed) => err(ErrorCode::Unavailable, e.to_string()),
        }
    };
    match frame {
        FrameRef::JsonReq(doc) => match Request::parse(doc.trim()) {
            Err(e) => {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                err(ErrorCode::BadRequest, e)
            }
            Ok(req) => json_request_step(req, shared, /* enveloped= */ true),
        },
        // Legacy v2 sparse score: u16 indices, always the default shard.
        FrameRef::ScoreSparse { gen, pairs } => {
            match screen(pairs.len() / 10, frame::validate_pairs_u16(pairs)) {
                Err(step) => step,
                Ok(()) => admit(
                    0,
                    gen,
                    frame::pairs_to_features_u16(pairs),
                    ReqKind::Score,
                    admission_opts(shared, None, None),
                    false,
                ),
            }
        }
        // The nnz knob caps sparse supports; dense payloads are bounded
        // by the frame-length cap alone (enforced at read time), like
        // dense JSON payloads are bounded by line length.
        FrameRef::ScoreDense { model, gen, vals } => {
            match screen(0, frame::validate_dense_vals(vals)) {
                Err(step) => step,
                Ok(()) => admit(
                    model,
                    gen,
                    frame::dense_to_features(vals),
                    ReqKind::Score,
                    admission_opts(shared, None, None),
                    false,
                ),
            }
        }
        FrameRef::ScoreSparse2 { model, gen, pairs } => {
            match screen(pairs.len() / 12, frame::validate_pairs_u32(pairs)) {
                Err(step) => step,
                Ok(()) => admit(
                    model,
                    gen,
                    frame::pairs_to_features_u32(pairs),
                    ReqKind::Score,
                    admission_opts(shared, None, None),
                    false,
                ),
            }
        }
        // v7 sparse score: the same screen as `ScoreSparse2`, plus the
        // request's own deadline and lane; the response comes back as
        // `SCORE_EX` so the degraded flag survives.
        FrameRef::ScoreSparseEx { model, gen, deadline_ms, lane, pairs } => {
            match screen(pairs.len() / 12, frame::validate_pairs_u32(pairs)) {
                Err(step) => step,
                Ok(()) => {
                    let (deadline, lane) = ex_admission(deadline_ms, lane);
                    admit(
                        model,
                        gen,
                        frame::pairs_to_features_u32(pairs),
                        ReqKind::Score,
                        admission_opts(shared, deadline, lane),
                        true,
                    )
                }
            }
        }
        FrameRef::ClassifySparse { model, gen, pairs, verbose } => {
            match screen(pairs.len() / 12, frame::validate_pairs_u32(pairs)) {
                Err(step) => step,
                Ok(()) => {
                    let kind =
                        if verbose { ReqKind::ClassifyVerbose } else { ReqKind::Classify };
                    admit(
                        model,
                        gen,
                        frame::pairs_to_features_u32(pairs),
                        kind,
                        admission_opts(shared, None, None),
                        false,
                    )
                }
            }
        }
        // v6/v7 batched scoring: one frame, one queue slot, one worker
        // wakeup. Structural layout was checked by the borrowed decode;
        // here each example is screened in place like a single sparse
        // score, with a failed screen demoted to that example's status
        // row instead of a whole-batch error. The v7 EX twin adds the
        // request's deadline and lane and answers as
        // `SCORE_BATCH_RESP_EX`.
        FrameRef::ScoreBatch { .. } | FrameRef::ScoreBatchEx { .. } => {
            let (model, gen, count, examples, opts, ex) = match frame {
                FrameRef::ScoreBatch { model, gen, count, examples } => {
                    (model, gen, count, examples, admission_opts(shared, None, None), false)
                }
                FrameRef::ScoreBatchEx { model, gen, deadline_ms, lane, count, examples } => {
                    let (deadline, lane) = ex_admission(deadline_ms, lane);
                    (model, gen, count, examples, admission_opts(shared, deadline, lane), true)
                }
                _ => unreachable!("outer arm admits only batch frames"),
            };
            if count > shared.max_batch_examples {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return err(
                    ErrorCode::BadRequest,
                    format!(
                        "batch count {count} exceeds server cap {}",
                        shared.max_batch_examples
                    ),
                );
            }
            let hub = match shared.registry.resolve_id(model) {
                Ok(hub) => hub,
                Err(e) => return err(ErrorCode::UnknownModel, e.to_string()),
            };
            let cap = effective_batch_cap(shared, &hub);
            if count > cap {
                shared.batch_shed.fetch_add(1, Ordering::Relaxed);
                return err(
                    ErrorCode::Overloaded,
                    format!(
                        "batch count {count} exceeds adaptive cap {cap} (queue under \
                         pressure); retry with a smaller batch"
                    ),
                );
            }
            let mut slots = Vec::with_capacity(count);
            let mut clean = Vec::with_capacity(count);
            for pairs in frame::batch_pairs(examples) {
                let nnz = pairs.len() / 12;
                if nnz > shared.max_nnz {
                    shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    slots.push(BatchSlot::Rejected {
                        code: ErrorCode::BadRequest,
                        msg: format!("nnz {nnz} exceeds server cap {}", shared.max_nnz),
                    });
                    continue;
                }
                match frame::validate_pairs_u32(pairs) {
                    Err(e) => {
                        shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                        let code = if e.contains("non-finite") {
                            ErrorCode::NonFinite
                        } else {
                            ErrorCode::BadRequest
                        };
                        slots.push(BatchSlot::Rejected { code, msg: e.to_string() });
                    }
                    Ok(()) => {
                        clean.push(frame::pairs_to_features_u32(pairs));
                        slots.push(BatchSlot::Submitted);
                    }
                }
            }
            // Whole-batch failures (unknown model above, wrong kind,
            // stale pin, overload, shutdown) stay one `ERROR` frame —
            // there is no partial outcome to report.
            match hub.submit_batch_opts(clean, gen, opts) {
                Ok((rx, serving)) => Step::Job(Job::PendingBatch {
                    wire: Wire::V2Binary { gen: serving, ex },
                    rx,
                    slots,
                }),
                Err(e @ HubError::StaleGeneration { .. }) => {
                    err(ErrorCode::StaleGeneration, e.to_string())
                }
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    err(ErrorCode::Overloaded, "overloaded".into())
                }
                Err(e @ HubError::DimMismatch { .. }) => {
                    err(ErrorCode::DimMismatch, e.to_string())
                }
                Err(e @ HubError::WrongKind { .. }) => err(ErrorCode::WrongModel, e.to_string()),
                Err(e @ HubError::Closed) => err(ErrorCode::Unavailable, e.to_string()),
            }
        }
        // v4 online learning: screen the payload like a score, then a
        // non-blocking hand-off to the shard's trainer queue — the ack
        // (or shed) is synchronous, the model update is not.
        FrameRef::LearnSparse { model, label, pairs } => {
            match screen(pairs.len() / 12, frame::validate_pairs_u32(pairs)) {
                Err(step) => step,
                Ok(()) => {
                    let features = frame::pairs_to_features_u32(pairs);
                    match shared.registry.learn_by_id(model, features, f64::from(label)) {
                        Ok((gen, seen)) => Step::Job(Job::Bytes(
                            Frame::LearnAck { gen, seen }.encode(),
                            WireClass::V2Binary,
                        )),
                        Err(RegistryError::LearnShed) => {
                            shared.overloaded.fetch_add(1, Ordering::Relaxed);
                            err(ErrorCode::Overloaded, "overloaded".into())
                        }
                        Err(e @ RegistryError::NoTrainer(_)) => {
                            err(ErrorCode::WrongModel, e.to_string())
                        }
                        Err(e @ RegistryError::TrainerClosed) => {
                            err(ErrorCode::Unavailable, e.to_string())
                        }
                        Err(
                            e @ (RegistryError::UnknownId(_) | RegistryError::UnknownName(_)),
                        ) => err(ErrorCode::UnknownModel, e.to_string()),
                        Err(RegistryError::Hub(e @ HubError::DimMismatch { .. })) => {
                            err(ErrorCode::DimMismatch, e.to_string())
                        }
                        Err(e) => err(ErrorCode::BadRequest, e.to_string()),
                    }
                }
            }
        }
        // Response ops arriving from a client are protocol abuse.
        FrameRef::Response(_) => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            err(ErrorCode::BadRequest, "response op sent by client".into())
        }
    }
}

fn writer_loop(stream: TcpStream, jrx: Receiver<Job>, shared: &Shared) {
    let mut out = BufWriter::new(stream);
    // One pooled render buffer for the connection's whole lifetime:
    // pending responses serialize into recycled memory, never a fresh
    // per-response Vec.
    let mut scratch = shared.pool.get();
    'outer: loop {
        let Ok(mut job) = jrx.recv() else { break };
        // Drain queued jobs before flushing, so a burst costs one syscall
        // instead of one per response — but never hold already-written
        // responses hostage to a computation that isn't done yet: flush
        // before blocking on an unready pending receiver.
        loop {
            scratch.clear();
            let (class, scored): (WireClass, u64) = match job {
                Job::Bytes(bytes, class) => {
                    scratch.extend_from_slice(&bytes);
                    (class, 0)
                }
                Job::Pending { wire, rx } => {
                    let resp = match rx.try_recv() {
                        Ok(resp) => Some(resp),
                        Err(TryRecvError::Empty) => {
                            if out.flush().is_err() {
                                break 'outer;
                            }
                            rx.recv().ok()
                        }
                        Err(TryRecvError::Disconnected) => None,
                    };
                    render_score_into(&wire, resp, &mut scratch);
                    (wire.class(), 1)
                }
                Job::PendingBatch { wire, rx, slots } => {
                    let results = match rx.try_recv() {
                        Ok(results) => Some(results),
                        Err(TryRecvError::Empty) => {
                            if out.flush().is_err() {
                                break 'outer;
                            }
                            rx.recv().ok()
                        }
                        Err(TryRecvError::Disconnected) => None,
                    };
                    render_batch_into(&wire, &slots, results, &mut scratch);
                    (wire.class(), slots.len() as u64)
                }
            };
            // Per-wire-class counters: bytes for every response, served
            // for score/classify outcomes (the migration signal; a
            // batch counts one per example, so batch and single traffic
            // read on the same scale).
            let counters = shared.wire(class);
            counters.bytes.fetch_add(scratch.len() as u64, Ordering::Relaxed);
            if scored > 0 {
                counters.served.fetch_add(scored, Ordering::Relaxed);
            }
            faultpoint::maybe_delay();
            if faultpoint::fires(faultpoint::Point::TornWrite) {
                // Crash the connection mid-response: emit a prefix of
                // the encoded bytes and die without the rest — the
                // client must spot the truncated frame and reconnect.
                let _ = out.write_all(&scratch[..scratch.len() / 2]);
                break 'outer;
            }
            if out.write_all(&scratch).is_err() {
                break 'outer;
            }
            match jrx.try_recv() {
                Ok(next) => job = next,
                Err(_) => break, // empty or disconnected: flush, then re-recv
            }
        }
        if out.flush().is_err() {
            break;
        }
    }
    let _ = out.flush();
    shared.pool.put(scratch);
}

/// Render an admitted request's outcome on its negotiated wire into a
/// caller-supplied buffer (appended — `None` = the worker generation
/// died before answering, which a drained shutdown should never
/// produce). On the binary wire this is allocation-free: score/classify
/// frames serialize straight into the reusable buffer.
pub(crate) fn render_score_into(wire: &Wire, resp: Option<ScoreResponse>, out: &mut Vec<u8>) {
    // Classify once; the codes map onto the v1 error strings.
    let outcome: std::result::Result<ScoreResponse, (ErrorCode, bool, &'static str)> = match resp
    {
        None => Err((ErrorCode::Unavailable, false, "service unavailable")),
        // A contained worker panic. Its sentinel is NaN-scored, so this
        // arm must precede the NaN dimension guard below. Retryable:
        // the panicking worker has already been respawned.
        Some(resp) if resp.is_internal_fault() => Err((
            ErrorCode::Internal,
            true,
            "internal error: evaluation panicked (worker respawned; retry)",
        )),
        // Deadline shed: the request expired in the queue and the
        // worker refused it at dequeue without scoring. Its sentinel is
        // also NaN-scored, so this arm too must precede the NaN guard.
        // Retryable: a retry carries a fresh deadline into what may be
        // a calmer queue.
        Some(resp) if resp.is_deadline_exceeded() => Err((
            ErrorCode::DeadlineExceeded,
            true,
            "deadline exceeded before scoring (shed at dequeue; retry)",
        )),
        // NaN marks the worker-level dimension guard; the hub screens
        // dimensions at admission, so this only fires if a reload changed
        // the model dim while the request was in flight.
        Some(resp) if resp.score.is_nan() => Err((
            ErrorCode::DimMismatch,
            true,
            "dimension mismatch (model reloaded mid-flight)",
        )),
        // Non-finite margins (e.g. inf weights in a reloaded snapshot)
        // cannot be serialized as JSON and are rejected on the binary
        // wire for parity.
        Some(resp) if !resp.score.is_finite() => {
            Err((ErrorCode::NonFinite, false, "non-finite score"))
        }
        Some(resp) => Ok(resp),
    };
    match wire {
        Wire::V1 { id } | Wire::V2Json { id } => {
            let resp = match outcome {
                Ok(r) => match (r.classify, r.per_voter) {
                    (Some(ci), Some(per_voter)) => Response::ClassifyVerbose {
                        id: *id,
                        label: ci.label,
                        votes: ci.votes,
                        voters: ci.voters,
                        features_evaluated: r.features_evaluated,
                        per_voter,
                        degraded: r.degraded,
                    },
                    (Some(ci), None) => Response::Classify {
                        id: *id,
                        label: ci.label,
                        votes: ci.votes,
                        voters: ci.voters,
                        features_evaluated: r.features_evaluated,
                        degraded: r.degraded,
                    },
                    (None, _) => Response::Score {
                        id: *id,
                        score: r.score,
                        features_evaluated: r.features_evaluated,
                        degraded: r.degraded,
                    },
                },
                Err((_, retryable, msg)) => {
                    Response::Error { id: *id, error: msg.into(), retryable }
                }
            };
            match wire {
                Wire::V2Json { .. } => {
                    Frame::JsonResp(resp.to_json().to_string_compact()).encode_into(out)
                }
                _ => out.extend_from_slice(resp.to_line().as_bytes()),
            }
        }
        Wire::V2Binary { gen, ex } => match outcome {
            Ok(r) => match (r.classify, r.per_voter) {
                (Some(ci), Some(per_voter)) => Frame::ClassVerbose {
                    gen: *gen,
                    label: ci.label,
                    votes: ci.votes,
                    voters: ci.voters,
                    evaluated: r.features_evaluated as u32,
                    per_voter,
                }
                .encode_into(out),
                (Some(ci), None) => Frame::Class {
                    gen: *gen,
                    label: ci.label,
                    votes: ci.votes,
                    voters: ci.voters,
                    evaluated: r.features_evaluated as u32,
                }
                .encode_into(out),
                // An EX request answers as SCORE_EX so the degraded
                // flag survives; legacy requests keep the legacy frame
                // byte-for-byte.
                (None, _) if *ex => Frame::ScoreEx {
                    gen: *gen,
                    flags: if r.degraded { frame::FLAG_DEGRADED } else { 0 },
                    evaluated: r.features_evaluated as u32,
                    score: r.score,
                }
                .encode_into(out),
                (None, _) => Frame::Score {
                    gen: *gen,
                    evaluated: r.features_evaluated as u32,
                    score: r.score,
                }
                .encode_into(out),
            },
            Err((code, retryable, msg)) => {
                Frame::Error { code, retryable, msg: msg.into() }.encode_into(out)
            }
        },
    }
}

/// Per-example outcome inside a batch, merged from the slot verdicts
/// and the worker's in-order results: a `Rejected` slot renders its
/// screen-time error, a `Submitted` slot consumes the next worker
/// result and classifies it exactly like [`render_score_into`] does
/// for a single score (NaN = mid-flight dim change, non-finite =
/// unserializable margin, missing = worker generation died).
fn batch_outcome<'a, I: Iterator<Item = ScoreResponse>>(
    slot: &'a BatchSlot,
    results: &mut I,
) -> std::result::Result<(f64, u32), (ErrorCode, &'a str)> {
    match slot {
        BatchSlot::Rejected { code, msg } => Err((*code, msg.as_str())),
        BatchSlot::Submitted => match results.next() {
            None => Err((ErrorCode::Unavailable, "service unavailable")),
            // Contained panic sentinel (NaN-scored): before the NaN
            // dimension guard, exactly as in `render_score_into`.
            Some(r) if r.is_internal_fault() => Err((
                ErrorCode::Internal,
                "internal error: evaluation panicked (worker respawned; retry)",
            )),
            // Deadline shed at dequeue: the whole batch expired, so
            // every submitted slot renders this row.
            Some(r) if r.is_deadline_exceeded() => Err((
                ErrorCode::DeadlineExceeded,
                "deadline exceeded before scoring (shed at dequeue; retry)",
            )),
            Some(r) if r.score.is_nan() => Err((
                ErrorCode::DimMismatch,
                "dimension mismatch (model reloaded mid-flight)",
            )),
            Some(r) if !r.score.is_finite() => Err((ErrorCode::NonFinite, "non-finite score")),
            Some(r) => Ok((r.score, r.features_evaluated as u32)),
        },
    }
}

/// Render a whole batch's outcomes on its negotiated wire into a
/// caller-supplied buffer (appended). On the binary wire this is one
/// `SCORE_BATCH_RESP` frame serialized allocation-free into the
/// reusable buffer; on the JSON wires it is one `score-batch` response
/// with a result row per example. `results` is `None` only when the
/// worker generation died before answering (a drained shutdown never
/// produces it); every `Submitted` slot then renders as unavailable.
pub(crate) fn render_batch_into(
    wire: &Wire,
    slots: &[BatchSlot],
    results: Option<Vec<ScoreResponse>>,
    out: &mut Vec<u8>,
) {
    // Batch-level degraded flag: the whole batch is scored by one
    // worker against one tier table, so any degraded row means the
    // batch was.
    let degraded = results.as_deref().is_some_and(|rs| rs.iter().any(|r| r.degraded));
    let mut results = results.into_iter().flatten();
    match wire {
        Wire::V1 { id } | Wire::V2Json { id } => {
            let rows = slots
                .iter()
                .map(|slot| match batch_outcome(slot, &mut results) {
                    Ok((score, evaluated)) => BatchRow::ok(score, evaluated as usize),
                    Err((_, msg)) => BatchRow::err(msg),
                })
                .collect();
            let resp = Response::ScoreBatch { id: *id, results: rows, degraded };
            match wire {
                Wire::V2Json { .. } => {
                    Frame::JsonResp(resp.to_json().to_string_compact()).encode_into(out)
                }
                _ => out.extend_from_slice(resp.to_line().as_bytes()),
            }
        }
        Wire::V2Binary { gen, ex } => {
            let mut enc = if *ex {
                Frame::begin_score_batch_resp_ex(
                    out,
                    *gen,
                    if degraded { frame::FLAG_DEGRADED } else { 0 },
                )
            } else {
                Frame::begin_score_batch_resp(out, *gen)
            };
            for slot in slots {
                match batch_outcome(slot, &mut results) {
                    Ok((score, evaluated)) => {
                        enc.push_result(frame::BATCH_STATUS_OK, evaluated, score)
                    }
                    Err((code, _)) => enc.push_result(code as u8, 0, 0.0),
                }
            }
            enc.finish();
        }
    }
}

/// The registry's shard table in wire form (the `models` op payload).
fn model_entries(shared: &Shared) -> Vec<ModelEntry> {
    shared
        .registry
        .infos()
        .into_iter()
        .map(|info| ModelEntry {
            name: info.name,
            id: info.id,
            kind: info.hub.kind.to_string(),
            gen: info.hub.gen,
            dim: info.hub.dim,
            voters: info.hub.voters,
            learn: info.learn,
            state: info.state.to_string(),
        })
        .collect()
}

fn report(shared: &Shared) -> StatsReport {
    let s = shared.registry.stats_total();
    let uptime = shared.started.elapsed().as_secs_f64().max(1e-9);
    StatsReport {
        served: s.served,
        avg_features: s.avg_features(),
        early_exit_rate: s.early_exit_rate(),
        batches: s.batches,
        features_p50: s.feature_percentile(0.50),
        features_p90: s.feature_percentile(0.90),
        features_p99: s.feature_percentile(0.99),
        accepted_conns: shared.accepted.load(Ordering::Relaxed),
        overloaded: shared.overloaded.load(Ordering::Relaxed),
        batch_shed: shared.batch_shed.load(Ordering::Relaxed),
        worker_panics: s.panics,
        deadline_sheds: s.deadline_sheds,
        degraded_responses: s.degraded,
        brownout_tier: s.tier,
        tier_transitions: s.tier_transitions,
        protocol_errors: shared.protocol_errors.load(Ordering::Relaxed),
        reloads: shared.registry.reloads(),
        uptime_s: uptime,
        req_per_s: s.served as f64 / uptime,
        wire_v1: shared.wire(WireClass::V1).snapshot(),
        wire_v2_json: shared.wire(WireClass::V2Json).snapshot(),
        wire_v2_binary: shared.wire(WireClass::V2Binary).snapshot(),
        models: shared
            .registry
            .per_shard_stats()
            .into_iter()
            .map(|shard| {
                let trainer = shard.trainer;
                let t = trainer.unwrap_or_default();
                ModelStatsReport {
                    name: shard.name,
                    state: shard.state.to_string(),
                    served: shard.stats.served,
                    avg_features: shard.stats.avg_features(),
                    early_exit_rate: shard.stats.early_exit_rate(),
                    gen: shard.gen,
                    reloads: shard.reloads,
                    trainer: trainer.is_some(),
                    learn_examples: t.examples,
                    learn_updates: t.updates,
                    learn_sheds: t.sheds,
                    learn_publishes: t.publishes,
                    learn_features: t.features,
                }
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn ephemeral_cfg() -> ServerConfig {
        ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serve_and_shutdown_is_clean() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "ephemeral port must be resolved");
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn drop_without_explicit_shutdown_does_not_hang() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        drop(server);
    }

    #[test]
    fn programmatic_reload_counts() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        assert_eq!(server.reload(snapshot(16)).unwrap(), 16);
        assert_eq!(server.stats().reloads, 1);
        server.shutdown();
    }

    /// The event backend speaks the identical wire protocol: negotiate,
    /// sparse frames, control ops, hot reload, clean shutdown.
    #[cfg(target_os = "linux")]
    #[test]
    fn event_loop_backend_serves_the_same_wire() {
        use crate::config::IoBackend;
        use crate::server::loadgen::Client;
        use crate::server::protocol::Response;
        let cfg = ServerConfig {
            listen: "127.0.0.1:0".into(),
            io_backend: IoBackend::EventLoop,
            event_threads: 2,
            ..Default::default()
        };
        let server = TcpServer::serve(&cfg, snapshot(16)).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.ping().unwrap();
        // v1 dense score.
        match client.score(vec![1.0; 16]).unwrap() {
            Response::Score { score, .. } => assert!(score > 0.0),
            other => panic!("expected score, got {other:?}"),
        }
        // Binary negotiation + native sparse frame.
        assert_eq!(client.negotiate().unwrap(), 7);
        match client.score_sparse(vec![3, 9], vec![1.0, 1.0], 0).unwrap() {
            Response::Score { score, features_evaluated, .. } => {
                assert!(score > 0.0);
                assert!(features_evaluated <= 2);
            }
            other => panic!("expected score, got {other:?}"),
        }
        // Dim mismatch stays a structured error, connection survives.
        match client.score(vec![1.0; 3]).unwrap() {
            Response::Error { retryable, .. } => assert!(!retryable),
            other => panic!("expected error, got {other:?}"),
        }
        // Hot reload through the same connection.
        let mut neg = snapshot(16);
        neg.weights = vec![-1.0; 16];
        client.reload(&neg).unwrap();
        match client.score_sparse(vec![3], vec![1.0], 0).unwrap() {
            Response::Score { score, .. } => assert!(score < 0.0, "reload flips the sign"),
            other => panic!("expected score, got {other:?}"),
        }
        let stats = client.stats().unwrap();
        assert!(stats.wire_v1.served >= 1);
        assert!(stats.wire_v2_binary.served >= 2);
        assert_eq!(stats.reloads, 1);
        drop(client);
        let final_stats = server.shutdown();
        assert!(final_stats.served >= 3);
        assert_eq!(final_stats.accepted_conns, 1);
    }

    /// `max_conns` sheds surplus connections with an immediate close on
    /// both backends.
    #[test]
    fn max_conns_refuses_surplus_connections() {
        use std::io::Read as _;
        let cfg = ServerConfig { listen: "127.0.0.1:0".into(), max_conns: 1, ..Default::default() };
        let server = TcpServer::serve(&cfg, snapshot(8)).unwrap();
        let addr = server.local_addr();
        let first = std::net::TcpStream::connect(addr).unwrap();
        // Give the accept loop time to admit the first connection.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut second = std::net::TcpStream::connect(addr).unwrap();
        let mut buf = [0u8; 1];
        second
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        // Must be a clean EOF — a read timeout would mean the server
        // admitted the surplus connection and left it hanging, which is
        // exactly the regression this test exists to catch.
        match second.read(&mut buf) {
            Ok(0) => {}
            other => panic!("surplus connection must see EOF, got {other:?}"),
        }
        drop(first);
        server.shutdown();
    }

    #[test]
    fn multi_shard_serve_lists_models_and_reloads_by_name() {
        let server = TcpServer::serve_models(
            &ephemeral_cfg(),
            vec![
                ("default".into(), snapshot(8).into()),
                ("wide".into(), snapshot(32).into()),
            ],
        )
        .unwrap();
        let models = server.models();
        assert_eq!(models.len(), 2);
        assert_eq!((models[0].name.as_str(), models[0].id, models[0].dim), ("default", 0, 8));
        assert_eq!((models[1].name.as_str(), models[1].id, models[1].dim), ("wide", 1, 32));
        assert_eq!(server.reload_model("wide", snapshot(64)).unwrap(), 64);
        assert_eq!(server.models()[1].gen, 2);
        assert_eq!(server.models()[0].gen, 1, "default shard untouched");
        assert!(server.reload_model("ghost", snapshot(8)).is_err());
        let stats = server.shutdown();
        assert_eq!(stats.models.len(), 2);
        assert_eq!(stats.models[1].reloads, 1);
    }
}
