//! TCP front-end: accept loop, per-connection reader/writer threads,
//! bounded-queue admission, per-connection protocol negotiation, model
//! routing, and the stats/models/reload control ops.
//!
//! ## Threading model
//!
//! One accept thread; per connection, a **reader** thread that decodes
//! requests and a **writer** thread that emits responses in request
//! order. Score/classify requests are routed through the
//! [`ModelRegistry`] — route resolution is lock-free (the shard table is
//! immutable) and happens **before** admission, so a hot reload of one
//! shard can never stall traffic on another — and admitted to the
//! target [`ModelHub`]'s bounded queue without blocking: if the queue is
//! full the reader immediately enqueues an explicit `overloaded` error
//! instead of buffering — load is shed at the edge, never accumulated.
//! Admitted requests travel to the writer as pending response
//! receivers, bounded by `max_pending_per_conn` (the per-connection
//! pipelining window): a slow consumer backpressures its own reader,
//! not the whole server.
//!
//! ## Protocol negotiation
//!
//! Every connection starts in v1 JSON-lines mode. A
//! `{"op":"hello","proto":N}` request with `N ≥ 2` flips it to the
//! length-prefixed binary framing of [`crate::server::frame`] — the
//! reader switches decoders after answering, and each queued job
//! carries its own rendering instructions, so the in-order response
//! stream stays consistent across the switch. A grant of 3 additionally
//! unlocks the model-routed v3 frame ops (dense score, u32-indexed
//! sparse score, classify). Clients that never send `hello` (all v1
//! clients) are served exactly as before, on the default shard.
//!
//! ## Control ops
//!
//! `stats` returns the aggregated [`StatsReport`] (throughput,
//! features-touched percentiles, early-exit rate, shed counts, plus
//! per-wire-class and per-shard splits); `models` lists the shard
//! table; `reload` hot-swaps one shard's serving model with zero
//! downtime (see [`ModelHub`]). All arrive over the same wire as
//! ordinary requests — in binary mode they ride inside
//! `JSON_REQ`/`JSON_RESP` envelope frames — so any connection can act
//! as a control channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::ServerConfig;
use crate::coordinator::service::{
    Features, ModelSnapshot, ReqKind, ScoreResponse, ServingModel,
};
use crate::error::{Error, Result};
use crate::server::frame::{ErrorCode, Frame, FrameError};
use crate::server::hub::{HubError, ModelHub};
use crate::server::protocol::{
    ModelEntry, ModelStatsReport, Request, Response, StatsReport, WireStats, PROTO_V2, PROTO_V3,
};
use crate::server::registry::{ModelRegistry, RegistryError, DEFAULT_MODEL};

/// Which wire class a response is rendered on — the key of the
/// per-protocol stats split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WireClass {
    /// v1 JSON line.
    V1,
    /// JSON document inside a v2+ envelope frame.
    V2Json,
    /// Native v2+ binary frame.
    V2Binary,
}

/// Served/bytes counters for one wire class.
#[derive(Default)]
struct WireCounters {
    served: AtomicU64,
    bytes: AtomicU64,
}

impl WireCounters {
    fn snapshot(&self) -> WireStats {
        WireStats {
            served: self.served.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

/// Server-wide shared state.
struct Shared {
    registry: ModelRegistry,
    shutting_down: AtomicBool,
    accepted: AtomicU64,
    overloaded: AtomicU64,
    protocol_errors: AtomicU64,
    started: Instant,
    /// Stream clones used to unblock connection readers at shutdown,
    /// keyed by connection id; entries are removed when the connection
    /// closes so long-lived servers don't leak fds.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    conn_joins: Mutex<Vec<JoinHandle<()>>>,
    max_pending: usize,
    max_frame_bytes: usize,
    max_nnz: usize,
    /// Per-wire-class served/bytes (indexed v1, v2-json, v2-binary).
    wire: [WireCounters; 3],
}

impl Shared {
    fn wire(&self, class: WireClass) -> &WireCounters {
        &self.wire[class as usize]
    }
}

/// A running TCP serving front-end.
///
/// Dropping the server shuts it down cleanly (stops accepting, closes
/// connections, drains every admitted request, joins all threads).
pub struct TcpServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_join: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `cfg.listen` and start serving `snapshot` as the single
    /// (default) shard — the original single-model entry point, kept
    /// for drop-in compatibility.
    pub fn serve(cfg: &ServerConfig, snapshot: ModelSnapshot) -> Result<TcpServer> {
        Self::serve_models(cfg, vec![(DEFAULT_MODEL.to_string(), snapshot.into())])
    }

    /// Bind `cfg.listen` and serve a registry of named model shards
    /// behind the one port. The first entry is the default shard (wire
    /// model id 0): it answers every request that does not name a
    /// model, so v1 single-model clients work unmodified.
    pub fn serve_models(
        cfg: &ServerConfig,
        models: Vec<(String, ServingModel)>,
    ) -> Result<TcpServer> {
        cfg.validate()?;
        let registry =
            ModelRegistry::new(models, cfg.max_batch, cfg.queue, cfg.workers, cfg.seed)?;
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| Error::io(&cfg.listen, e))?;
        let local_addr = listener.local_addr().map_err(|e| Error::io(&cfg.listen, e))?;
        let shared = Arc::new(Shared {
            registry,
            shutting_down: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            started: Instant::now(),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            conn_joins: Mutex::new(Vec::new()),
            max_pending: cfg.max_pending_per_conn,
            max_frame_bytes: cfg.max_frame_bytes,
            max_nnz: cfg.max_nnz,
            wire: Default::default(),
        });
        let accept_shared = shared.clone();
        let accept_join = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(TcpServer { shared, local_addr, accept_join: Some(accept_join) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Current server statistics (same payload as the `stats` op).
    pub fn stats(&self) -> StatsReport {
        report(&self.shared)
    }

    /// Programmatic hot reload of the default shard (same semantics as
    /// an un-routed `reload` op).
    pub fn reload(
        &self,
        model: impl Into<ServingModel>,
    ) -> std::result::Result<usize, HubError> {
        self.shared.registry.default_hub().reload(model)
    }

    /// Programmatic hot reload of a named shard (same semantics as a
    /// routed `reload` op).
    pub fn reload_model(
        &self,
        name: &str,
        model: impl Into<ServingModel>,
    ) -> std::result::Result<usize, RegistryError> {
        self.shared.registry.reload(Some(name), model.into())
    }

    /// The registry's shard table (same payload as the `models` op).
    pub fn models(&self) -> Vec<ModelEntry> {
        model_entries(&self.shared)
    }

    /// Block on the accept loop. It only exits if the listener itself
    /// fails (in normal operation the process runs until killed — there
    /// is no cross-thread stop signal once `self` is consumed; use
    /// [`Self::shutdown`] instead of `wait` when you need a programmatic
    /// stop). Cleans up if the loop ever does exit.
    pub fn wait(mut self) {
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        self.teardown_connections();
        self.shared.registry.shutdown();
    }

    /// Stop accepting, drain and answer every admitted request, join all
    /// threads, and return the final statistics.
    pub fn shutdown(mut self) -> StatsReport {
        self.shutdown_impl();
        report(&self.shared)
    }

    fn shutdown_impl(&mut self) {
        let Some(accept_join) = self.accept_join.take() else {
            return; // already shut down
        };
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so it observes the flag.
        let _ = TcpStream::connect(self.local_addr);
        let _ = accept_join.join();
        self.teardown_connections();
        self.shared.registry.shutdown();
    }

    fn teardown_connections(&self) {
        // Unblock every connection reader; EOF ends the reader, which
        // drops the job channel, which lets the writer drain and exit.
        for (_, stream) in self.shared.conns.lock().unwrap().drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let joins = std::mem::take(&mut *self.shared.conn_joins.lock().unwrap());
        for join in joins {
            let _ = join.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.accepted.fetch_add(1, Ordering::Relaxed);
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(conn_id, clone);
        }
        let conn_shared = shared.clone();
        let join = std::thread::spawn(move || {
            handle_conn(stream, &conn_shared);
            // Release this connection's shutdown clone (fd) as soon as
            // the connection ends, not at server teardown.
            conn_shared.conns.lock().unwrap().remove(&conn_id);
        });
        let mut joins = shared.conn_joins.lock().unwrap();
        // Reap handles of connections that already finished so a
        // long-running server doesn't accumulate one per connection.
        joins.retain(|j| !j.is_finished());
        joins.push(join);
    }
}

/// How a pending score/classify response must be rendered — decided at
/// admission time, so the writer needs no codec state of its own and
/// the v1→v2 switch stays consistent across the in-order job stream.
enum Wire {
    /// v1 JSON line, echoing the optional request id.
    V1 { id: Option<u64> },
    /// v2+ binary `SCORE`/`CLASS`/`ERROR` frame, stamped with the
    /// serving generation captured at admission (classify pendings
    /// render as `CLASS`, score pendings as `SCORE`).
    V2Binary { gen: u32 },
    /// v2+ `JSON_RESP` envelope frame (a JSON-op request on a binary
    /// connection, e.g. a dense score through the envelope).
    V2Json { id: Option<u64> },
}

impl Wire {
    fn class(&self) -> WireClass {
        match self {
            Wire::V1 { .. } => WireClass::V1,
            Wire::V2Json { .. } => WireClass::V2Json,
            Wire::V2Binary { .. } => WireClass::V2Binary,
        }
    }
}

/// What the reader hands the writer, in request order.
enum Job {
    /// Fully-encoded response bytes (a JSON line or a binary frame),
    /// tagged with the wire class for the byte counters.
    Bytes(Vec<u8>, WireClass),
    /// An admitted score/classify request whose response is still being
    /// computed.
    Pending { wire: Wire, rx: Receiver<ScoreResponse> },
}

/// Reader-side verdict for one decoded request.
enum Step {
    /// Enqueue this job and keep reading.
    Job(Job),
    /// Enqueue, then switch the connection to binary framing.
    JobThenBinary(Job),
    /// Enqueue, then close the connection (unrecoverable stream state).
    JobThenClose(Job),
    /// Close immediately.
    Close,
}

fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let (jtx, jrx) = sync_channel::<Job>(shared.max_pending);
    let writer_shared = shared.clone();
    let writer = std::thread::spawn(move || writer_loop(stream, jrx, &writer_shared));

    let mut binary = false;
    let mut line = String::new();
    loop {
        let step = if binary {
            read_binary_step(&mut reader, shared)
        } else {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => Step::Close,
                Ok(_) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    json_step(trimmed, shared)
                }
            }
        };
        match step {
            Step::Job(job) => {
                if jtx.send(job).is_err() {
                    break; // writer gone (connection dead)
                }
            }
            Step::JobThenBinary(job) => {
                if jtx.send(job).is_err() {
                    break;
                }
                binary = true;
            }
            Step::JobThenClose(job) => {
                let _ = jtx.send(job);
                break;
            }
            Step::Close => break,
        }
    }
    drop(jtx); // writer drains the remaining jobs, then exits
    let _ = writer.join();
}

/// Handle one v1 JSON line.
fn json_step(line: &str, shared: &Shared) -> Step {
    match Request::parse(line) {
        Err(e) => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(Job::Bytes(
                Response::Error { id: None, error: e, retryable: false }.to_line().into_bytes(),
                WireClass::V1,
            ))
        }
        Ok(Request::Hello { proto }) => {
            // Grant the highest version both sides speak; v1 keeps the
            // connection on JSON lines (transparent fallback).
            let granted = proto.min(PROTO_V3).max(1);
            // One snapshot: (gen, dim) must not tear across a reload.
            // The handshake advertises the default shard, which is what
            // single-model clients will be talking to.
            let (gen, dim) = shared.registry.default_hub().serving_info();
            let resp = Response::Hello { proto: granted, gen, dim };
            let job = Job::Bytes(resp.to_line().into_bytes(), WireClass::V1);
            if granted >= PROTO_V2 {
                Step::JobThenBinary(job)
            } else {
                Step::Job(job)
            }
        }
        Ok(req) => json_request_step(req, shared, /* enveloped= */ false),
    }
}

/// Handle a JSON-op request arriving either as a bare v1 line
/// (`enveloped = false`) or inside a v2 `JSON_REQ` frame (`true`); the
/// response rides the matching vehicle.
fn json_request_step(req: Request, shared: &Shared, enveloped: bool) -> Step {
    let class = if enveloped { WireClass::V2Json } else { WireClass::V1 };
    let render = |resp: Response| -> Job {
        if enveloped {
            Job::Bytes(Frame::JsonResp(resp.to_json().to_string_compact()).encode(), class)
        } else {
            Job::Bytes(resp.to_line().into_bytes(), class)
        }
    };
    match req {
        Request::Hello { .. } => {
            // Renegotiation inside a binary connection is not a thing;
            // as a bare v1 line it is handled by `json_step`.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(render(Response::Error {
                id: None,
                error: "hello: already negotiated".into(),
                retryable: false,
            }))
        }
        Request::Ping => Step::Job(render(Response::Pong)),
        Request::Stats => Step::Job(render(Response::Stats(report(shared)))),
        Request::Models => Step::Job(render(Response::Models(model_entries(shared)))),
        Request::Reload { model, snapshot } => {
            match shared.registry.reload(model.as_deref(), snapshot) {
                Ok(dim) => Step::Job(render(Response::Reloaded { dim })),
                Err(e) => Step::Job(render(Response::Error {
                    id: None,
                    error: e.to_string(),
                    retryable: false,
                })),
            }
        }
        Request::Score { .. } | Request::Classify { .. } => {
            let (id, model, features, kind) = match req {
                Request::Score { id, model, features } => (id, model, features, ReqKind::Score),
                Request::Classify { id, model, features } => {
                    (id, model, features, ReqKind::Classify)
                }
                _ => unreachable!("outer arm admits only score/classify"),
            };
            // The nnz knob bounds per-request compute on every wire, not
            // just the binary one — a classify amplifies each coordinate
            // by C(C-1)/2 voters, so an uncapped JSON support would
            // bypass the operator's limit entirely.
            if matches!(features, Features::Sparse { .. }) && features.nnz() > shared.max_nnz {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return Step::Job(render(Response::Error {
                    id,
                    error: format!(
                        "nnz {} exceeds server cap {}",
                        features.nnz(),
                        shared.max_nnz
                    ),
                    retryable: false,
                }));
            }
            // Resolve the route before admission: an unknown model is a
            // clean structured error, and a valid one hands us the
            // shard's hub without any registry-wide locking.
            let hub = match shared.registry.resolve_name(model.as_deref()) {
                Ok((_, hub)) => hub,
                Err(e) => {
                    return Step::Job(render(Response::Error {
                        id,
                        error: e.to_string(),
                        retryable: false,
                    }))
                }
            };
            match hub.submit_pinned(features, 0, kind) {
                Ok((rx, _)) => {
                    let wire = if enveloped { Wire::V2Json { id } } else { Wire::V1 { id } };
                    Step::Job(Job::Pending { wire, rx })
                }
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    Step::Job(render(Response::Error {
                        id,
                        error: "overloaded".into(),
                        retryable: true,
                    }))
                }
                // StaleGeneration cannot happen on an unpinned submit;
                // fold it with the other non-retryable rejections for
                // exhaustiveness.
                Err(
                    e @ (HubError::DimMismatch { .. }
                    | HubError::StaleGeneration { .. }
                    | HubError::WrongKind { .. }),
                ) => Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: false,
                })),
                Err(HubError::Closed) => Step::Close,
            }
        }
    }
}

/// Read and handle one v2/v3 binary frame.
fn read_binary_step(reader: &mut BufReader<TcpStream>, shared: &Shared) -> Step {
    let frame = match Frame::read_from(reader, shared.max_frame_bytes) {
        Ok(frame) => frame,
        Err(FrameError::Eof) => return Step::Close,
        Err(e) => {
            // Framing is lost — a byte stream cannot resync after a bad
            // prefix. Report once, then close.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return Step::JobThenClose(Job::Bytes(
                Frame::Error {
                    code: ErrorCode::BadFrame,
                    retryable: false,
                    msg: e.to_string(),
                }
                .encode(),
                WireClass::V2Binary,
            ));
        }
    };
    let err = |code: ErrorCode, msg: String| -> Step {
        Step::Job(Job::Bytes(
            Frame::Error { code, retryable: code.retryable(), msg }.encode(),
            WireClass::V2Binary,
        ))
    };
    // Route, validate, and admit one native score/classify payload: the
    // shared tail of every binary frame op. The pin check, admission,
    // and generation stamp all happen under one hub critical section:
    // the stamped generation is the one whose workers answer, even
    // across a racing reload.
    let admit = |model: u16, gen: u32, features: Features, kind: ReqKind| -> Step {
        // The nnz knob caps sparse supports; dense payloads are bounded
        // by the frame-length cap alone (enforced at `read_from`), like
        // dense JSON payloads are bounded by line length.
        if matches!(features, Features::Sparse { .. }) && features.nnz() > shared.max_nnz {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return err(
                ErrorCode::BadRequest,
                format!("nnz {} exceeds server cap {}", features.nnz(), shared.max_nnz),
            );
        }
        if let Err(e) = features.validate() {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let code = if e.contains("non-finite") {
                ErrorCode::NonFinite
            } else {
                ErrorCode::BadRequest
            };
            return err(code, e);
        }
        // Route resolution is lock-free and happens before admission: a
        // reload of another shard can never delay this request.
        let hub = match shared.registry.resolve_id(model) {
            Ok(hub) => hub,
            Err(e) => return err(ErrorCode::UnknownModel, e.to_string()),
        };
        match hub.submit_pinned(features, gen, kind) {
            Ok((rx, serving)) => {
                Step::Job(Job::Pending { wire: Wire::V2Binary { gen: serving }, rx })
            }
            Err(e @ HubError::StaleGeneration { .. }) => {
                err(ErrorCode::StaleGeneration, e.to_string())
            }
            Err(HubError::Overloaded) => {
                shared.overloaded.fetch_add(1, Ordering::Relaxed);
                err(ErrorCode::Overloaded, "overloaded".into())
            }
            Err(e @ HubError::DimMismatch { .. }) => err(ErrorCode::DimMismatch, e.to_string()),
            Err(e @ HubError::WrongKind { .. }) => err(ErrorCode::WrongModel, e.to_string()),
            Err(HubError::Closed) => Step::Close,
        }
    };
    match frame {
        Frame::JsonReq(doc) => match Request::parse(doc.trim()) {
            Err(e) => {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                err(ErrorCode::BadRequest, e)
            }
            Ok(req) => json_request_step(req, shared, /* enveloped= */ true),
        },
        // Legacy v2 sparse score: u16 indices, always the default shard.
        Frame::ScoreSparse { gen, idx, val } => {
            let features =
                Features::Sparse { idx: idx.into_iter().map(u32::from).collect(), val };
            admit(0, gen, features, ReqKind::Score)
        }
        Frame::ScoreDense { model, gen, val } => {
            admit(model, gen, Features::Dense(val), ReqKind::Score)
        }
        Frame::ScoreSparse2 { model, gen, idx, val } => {
            admit(model, gen, Features::Sparse { idx, val }, ReqKind::Score)
        }
        Frame::ClassifySparse { model, gen, idx, val } => {
            admit(model, gen, Features::Sparse { idx, val }, ReqKind::Classify)
        }
        // Response ops arriving from a client are protocol abuse.
        Frame::Score { .. } | Frame::Error { .. } | Frame::JsonResp(_) | Frame::Class { .. } => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            err(ErrorCode::BadRequest, "response op sent by client".into())
        }
    }
}

fn writer_loop(stream: TcpStream, jrx: Receiver<Job>, shared: &Shared) {
    let mut out = BufWriter::new(stream);
    'outer: loop {
        let Ok(mut job) = jrx.recv() else { break };
        // Drain queued jobs before flushing, so a burst costs one syscall
        // instead of one per response — but never hold already-written
        // responses hostage to a computation that isn't done yet: flush
        // before blocking on an unready pending receiver.
        loop {
            let (bytes, class, scored) = match job {
                Job::Bytes(bytes, class) => (bytes, class, false),
                Job::Pending { wire, rx } => {
                    let resp = match rx.try_recv() {
                        Ok(resp) => Some(resp),
                        Err(TryRecvError::Empty) => {
                            if out.flush().is_err() {
                                break 'outer;
                            }
                            rx.recv().ok()
                        }
                        Err(TryRecvError::Disconnected) => None,
                    };
                    (render_score(&wire, resp), wire.class(), true)
                }
            };
            // Per-wire-class counters: bytes for every response, served
            // for score/classify outcomes (the migration signal).
            let counters = shared.wire(class);
            counters.bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
            if scored {
                counters.served.fetch_add(1, Ordering::Relaxed);
            }
            if out.write_all(&bytes).is_err() {
                break 'outer;
            }
            match jrx.try_recv() {
                Ok(next) => job = next,
                Err(_) => break, // empty or disconnected: flush, then re-recv
            }
        }
        if out.flush().is_err() {
            break;
        }
    }
    let _ = out.flush();
}

/// Render an admitted request's outcome on its negotiated wire (`None`
/// = the worker generation died before answering, which a drained
/// shutdown should never produce).
fn render_score(wire: &Wire, resp: Option<ScoreResponse>) -> Vec<u8> {
    // Classify once; the codes map onto the v1 error strings.
    let outcome: std::result::Result<ScoreResponse, (ErrorCode, bool, &'static str)> = match resp
    {
        None => Err((ErrorCode::Unavailable, false, "service unavailable")),
        // NaN marks the worker-level dimension guard; the hub screens
        // dimensions at admission, so this only fires if a reload changed
        // the model dim while the request was in flight.
        Some(resp) if resp.score.is_nan() => Err((
            ErrorCode::DimMismatch,
            true,
            "dimension mismatch (model reloaded mid-flight)",
        )),
        // Non-finite margins (e.g. inf weights in a reloaded snapshot)
        // cannot be serialized as JSON and are rejected on the binary
        // wire for parity.
        Some(resp) if !resp.score.is_finite() => {
            Err((ErrorCode::NonFinite, false, "non-finite score"))
        }
        Some(resp) => Ok(resp),
    };
    match wire {
        Wire::V1 { id } | Wire::V2Json { id } => {
            let resp = match outcome {
                Ok(r) => match r.classify {
                    Some(ci) => Response::Classify {
                        id: *id,
                        label: ci.label,
                        votes: ci.votes,
                        voters: ci.voters,
                        features_evaluated: r.features_evaluated,
                    },
                    None => Response::Score {
                        id: *id,
                        score: r.score,
                        features_evaluated: r.features_evaluated,
                    },
                },
                Err((_, retryable, msg)) => {
                    Response::Error { id: *id, error: msg.into(), retryable }
                }
            };
            match wire {
                Wire::V2Json { .. } => {
                    Frame::JsonResp(resp.to_json().to_string_compact()).encode()
                }
                _ => resp.to_line().into_bytes(),
            }
        }
        Wire::V2Binary { gen } => match outcome {
            Ok(r) => match r.classify {
                Some(ci) => Frame::Class {
                    gen: *gen,
                    label: ci.label,
                    votes: ci.votes,
                    voters: ci.voters,
                    evaluated: r.features_evaluated as u32,
                }
                .encode(),
                None => Frame::Score {
                    gen: *gen,
                    evaluated: r.features_evaluated as u32,
                    score: r.score,
                }
                .encode(),
            },
            Err((code, retryable, msg)) => {
                Frame::Error { code, retryable, msg: msg.into() }.encode()
            }
        },
    }
}

/// The registry's shard table in wire form (the `models` op payload).
fn model_entries(shared: &Shared) -> Vec<ModelEntry> {
    shared
        .registry
        .infos()
        .into_iter()
        .map(|info| ModelEntry {
            name: info.name,
            id: info.id,
            kind: info.hub.kind.to_string(),
            gen: info.hub.gen,
            dim: info.hub.dim,
            voters: info.hub.voters,
        })
        .collect()
}

fn report(shared: &Shared) -> StatsReport {
    let s = shared.registry.stats_total();
    let uptime = shared.started.elapsed().as_secs_f64().max(1e-9);
    StatsReport {
        served: s.served,
        avg_features: s.avg_features(),
        early_exit_rate: s.early_exit_rate(),
        batches: s.batches,
        features_p50: s.feature_percentile(0.50),
        features_p90: s.feature_percentile(0.90),
        features_p99: s.feature_percentile(0.99),
        accepted_conns: shared.accepted.load(Ordering::Relaxed),
        overloaded: shared.overloaded.load(Ordering::Relaxed),
        protocol_errors: shared.protocol_errors.load(Ordering::Relaxed),
        reloads: shared.registry.reloads(),
        uptime_s: uptime,
        req_per_s: s.served as f64 / uptime,
        wire_v1: shared.wire(WireClass::V1).snapshot(),
        wire_v2_json: shared.wire(WireClass::V2Json).snapshot(),
        wire_v2_binary: shared.wire(WireClass::V2Binary).snapshot(),
        models: shared
            .registry
            .per_shard_stats()
            .into_iter()
            .map(|shard| ModelStatsReport {
                name: shard.name,
                served: shard.stats.served,
                avg_features: shard.stats.avg_features(),
                early_exit_rate: shard.stats.early_exit_rate(),
                gen: shard.gen,
                reloads: shard.reloads,
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn ephemeral_cfg() -> ServerConfig {
        ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serve_and_shutdown_is_clean() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "ephemeral port must be resolved");
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn drop_without_explicit_shutdown_does_not_hang() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        drop(server);
    }

    #[test]
    fn programmatic_reload_counts() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        assert_eq!(server.reload(snapshot(16)).unwrap(), 16);
        assert_eq!(server.stats().reloads, 1);
        server.shutdown();
    }

    #[test]
    fn multi_shard_serve_lists_models_and_reloads_by_name() {
        let server = TcpServer::serve_models(
            &ephemeral_cfg(),
            vec![
                ("default".into(), snapshot(8).into()),
                ("wide".into(), snapshot(32).into()),
            ],
        )
        .unwrap();
        let models = server.models();
        assert_eq!(models.len(), 2);
        assert_eq!((models[0].name.as_str(), models[0].id, models[0].dim), ("default", 0, 8));
        assert_eq!((models[1].name.as_str(), models[1].id, models[1].dim), ("wide", 1, 32));
        assert_eq!(server.reload_model("wide", snapshot(64)).unwrap(), 64);
        assert_eq!(server.models()[1].gen, 2);
        assert_eq!(server.models()[0].gen, 1, "default shard untouched");
        assert!(server.reload_model("ghost", snapshot(8)).is_err());
        let stats = server.shutdown();
        assert_eq!(stats.models.len(), 2);
        assert_eq!(stats.models[1].reloads, 1);
    }
}
