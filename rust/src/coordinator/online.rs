//! Online-learning subsystem behind the wire: the `learn` op's engine.
//!
//! One [`OnlineTrainer`] per registry shard owns a live attentive
//! Pegasos ([`crate::learner::pegasos::BoundedPegasos`], built via
//! [`crate::coordinator::factory::build_wire_pegasos`]) on a background
//! thread. Labeled examples arrive through a bounded MPSC queue —
//! enqueue never blocks the wire: when the queue is full the example is
//! *shed* with an explicit, retryable ack, mirroring the score path's
//! admission control. The thread densifies each example, runs one
//! attentive `process` step (spending O(√n) features on easy examples,
//! per the paper), and periodically publishes an immutable
//! [`ModelSnapshot`] into the shard's [`ModelHub`] generation swap:
//! after every K updates and/or T milliseconds, whichever fires first.
//! Concurrent `score`/`classify` traffic picks up the new generation
//! through the hub's existing swap — zero added cost on the scoring hot
//! path.
//!
//! Determinism: a single consumer thread processes examples in queue
//! order with a config-seeded learner, so the same accepted sequence
//! reproduces the same weights as an offline run (tested below).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TrainerWireConfig;
use crate::coordinator::factory::build_wire_pegasos;
use crate::coordinator::service::{Features, ModelSnapshot, ServingModel};
use crate::learner::OnlineLearner;
use crate::server::hub::ModelHub;

/// Poll interval when no time-based publish is pending — only bounds
/// how quickly the thread notices a dropped sender, not learn latency.
const IDLE_POLL_MS: u64 = 250;

/// One labeled example bound for a shard's trainer.
#[derive(Debug, Clone)]
pub struct LearnExample {
    /// Feature vector (sparse or dense).
    pub features: Features,
    /// Label, ±1.
    pub label: f64,
}

/// Why a `learn` submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnError {
    /// The bounded learn queue is full; the example was shed. Retryable.
    Shed,
    /// The trainer has shut down.
    Closed,
}

/// Live counters for one shard's trainer. `examples` counts accepted
/// (enqueued) submissions; `updates`/`features` are bumped by the
/// trainer thread as it processes; `sheds` counts queue-full rejects;
/// `publishes` counts snapshot generations pushed into the hub.
#[derive(Debug, Default)]
pub struct TrainerStats {
    /// Examples accepted into the queue.
    pub examples: AtomicU64,
    /// Model updates applied.
    pub updates: AtomicU64,
    /// Examples shed on queue overflow.
    pub sheds: AtomicU64,
    /// Snapshots published into the hub.
    pub publishes: AtomicU64,
    /// Feature evaluations spent while learning (the paper's budget
    /// axis: sub-linear per example when the attentive boundary fires).
    pub features: AtomicU64,
}

/// A point-in-time copy of [`TrainerStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrainerStatsSnapshot {
    /// Examples accepted into the queue.
    pub examples: u64,
    /// Model updates applied.
    pub updates: u64,
    /// Examples shed on queue overflow.
    pub sheds: u64,
    /// Snapshots published into the hub.
    pub publishes: u64,
    /// Feature evaluations spent while learning.
    pub features: u64,
}

impl TrainerStats {
    /// Copy the counters (relaxed: monotone counters, not an invariant).
    pub fn snapshot(&self) -> TrainerStatsSnapshot {
        TrainerStatsSnapshot {
            examples: self.examples.load(Ordering::Relaxed),
            updates: self.updates.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            features: self.features.load(Ordering::Relaxed),
        }
    }
}

/// Where published snapshots go. Production is a [`ModelHub`] reload;
/// tests capture snapshots directly. Returns whether the publish stuck.
pub type PublishSink = Box<dyn FnMut(ModelSnapshot) -> bool + Send>;

/// Handle to one shard's background trainer thread. Shared behind the
/// registry (`&self` API); shutdown is idempotent and joins the thread.
pub struct OnlineTrainer {
    tx: Mutex<Option<SyncSender<LearnExample>>>,
    join: Mutex<Option<JoinHandle<()>>>,
    stats: Arc<TrainerStats>,
}

impl std::fmt::Debug for OnlineTrainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OnlineTrainer").field("stats", &self.stats.snapshot()).finish()
    }
}

impl OnlineTrainer {
    /// Spawn a trainer publishing into `hub`'s generation swap. If the
    /// shard currently serves a binary model with trained (nonzero)
    /// weights, the trainer **warm-starts** from that snapshot — weights,
    /// Pegasos step clock, and variance prior — instead of `w = 0`, so
    /// attaching a trainer to a loaded shard is immediately incremental
    /// rather than relearning from scratch.
    pub fn spawn(hub: Arc<ModelHub>, cfg: &TrainerWireConfig, dim: usize) -> Self {
        let init = match &*hub.serving_model() {
            ServingModel::Binary(snap) => Some(snap.clone()),
            _ => None,
        };
        Self::spawn_inner(cfg, dim, init, Box::new(move |snap| hub.reload(snap).is_ok()))
    }

    /// Spawn a trainer publishing into an arbitrary sink (tests, tools).
    /// Always cold-starts from `w = 0`.
    pub fn spawn_with_sink(cfg: &TrainerWireConfig, dim: usize, sink: PublishSink) -> Self {
        Self::spawn_inner(cfg, dim, None, sink)
    }

    fn spawn_inner(
        cfg: &TrainerWireConfig,
        dim: usize,
        init: Option<ModelSnapshot>,
        sink: PublishSink,
    ) -> Self {
        let (tx, rx) = sync_channel(cfg.queue.max(1));
        let stats = Arc::new(TrainerStats::default());
        let thread_stats = Arc::clone(&stats);
        let cfg = cfg.clone();
        let join = std::thread::Builder::new()
            .name("online-trainer".into())
            .spawn(move || run_trainer(rx, cfg, dim, init, thread_stats, sink))
            .expect("spawn online trainer thread");
        Self { tx: Mutex::new(Some(tx)), join: Mutex::new(Some(join)), stats }
    }

    /// Submit one labeled example without blocking. On success returns
    /// the cumulative accepted-example count (for the wire ack); a full
    /// queue sheds the example and reports [`LearnError::Shed`].
    pub fn learn(&self, features: Features, label: f64) -> Result<u64, LearnError> {
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref().ok_or(LearnError::Closed)?;
        match tx.try_send(LearnExample { features, label }) {
            Ok(()) => Ok(self.stats.examples.fetch_add(1, Ordering::Relaxed) + 1),
            Err(TrySendError::Full(_)) => {
                self.stats.sheds.fetch_add(1, Ordering::Relaxed);
                Err(LearnError::Shed)
            }
            Err(TrySendError::Disconnected(_)) => Err(LearnError::Closed),
        }
    }

    /// Live counters.
    pub fn stats(&self) -> TrainerStatsSnapshot {
        self.stats.snapshot()
    }

    /// Drop the queue and join the thread, which drains remaining
    /// examples and publishes a final snapshot if anything changed
    /// since the last publish. Idempotent.
    pub fn shutdown(&self) {
        drop(self.tx.lock().unwrap().take());
        if let Some(join) = self.join.lock().unwrap().take() {
            let _ = join.join();
        }
    }
}

impl Drop for OnlineTrainer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The trainer thread: consume → densify → attentive step → publish on
/// K updates and/or T ms, whichever first; final publish on shutdown.
fn run_trainer(
    rx: Receiver<LearnExample>,
    cfg: TrainerWireConfig,
    dim: usize,
    init: Option<ModelSnapshot>,
    stats: Arc<TrainerStats>,
    mut sink: PublishSink,
) {
    let mut learner = build_wire_pegasos(&cfg, dim);
    if let Some(snap) = init {
        // No-op on zero or malformed snapshots, so a freshly provisioned
        // shard still trains exactly like an offline from-zero run.
        learner.warm_start(&snap.weights, snap.var_sn);
    }
    let mut updates_since_publish = 0u64;
    let mut dirty = false;
    let mut last_publish = Instant::now();
    let time_cadence =
        (cfg.publish_every_ms > 0).then(|| Duration::from_millis(cfg.publish_every_ms));

    let mut publish = |learner: &mut _, dirty: &mut bool, updates: &mut u64, last: &mut Instant| {
        let snap = ModelSnapshot::from_trained(learner, cfg.boundary.clone(), cfg.policy);
        if sink(snap) {
            stats.publishes.fetch_add(1, Ordering::Relaxed);
        }
        *dirty = false;
        *updates = 0;
        *last = Instant::now();
    };

    loop {
        if dirty {
            if let Some(t) = time_cadence {
                if last_publish.elapsed() >= t {
                    publish(&mut learner, &mut dirty, &mut updates_since_publish, &mut last_publish);
                }
            }
        }
        let timeout = match (dirty, time_cadence) {
            (true, Some(t)) => t.saturating_sub(last_publish.elapsed()),
            _ => Duration::from_millis(IDLE_POLL_MS),
        };
        match rx.recv_timeout(timeout) {
            Ok(ex) => {
                let x = ex.features.densify(dim);
                let info = learner.process(&x, ex.label);
                stats.features.fetch_add(info.evaluated as u64, Ordering::Relaxed);
                dirty = true;
                if info.updated {
                    stats.updates.fetch_add(1, Ordering::Relaxed);
                    updates_since_publish += 1;
                    if cfg.publish_every_updates > 0
                        && updates_since_publish >= cfg.publish_every_updates
                    {
                        publish(
                            &mut learner,
                            &mut dirty,
                            &mut updates_since_publish,
                            &mut last_publish,
                        );
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if dirty {
                    publish(&mut learner, &mut dirty, &mut updates_since_publish, &mut last_publish);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn test_cfg() -> TrainerWireConfig {
        TrainerWireConfig {
            queue: 64,
            publish_every_updates: 0,
            publish_every_ms: 0, // direct spawns skip validate(); shutdown publishes
            lambda: 1e-2,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::WeightSampled,
            seed: 11,
            ..Default::default()
        }
    }

    fn capture_sink() -> (Arc<Mutex<Vec<ModelSnapshot>>>, PublishSink) {
        let published = Arc::new(Mutex::new(Vec::new()));
        let sink_ref = Arc::clone(&published);
        let sink: PublishSink = Box::new(move |snap| {
            sink_ref.lock().unwrap().push(snap);
            true
        });
        (published, sink)
    }

    /// Synthetic separable stream: sign of the sum of two informative
    /// coordinates, embedded sparsely in `dim`.
    fn stream(n: usize, dim: usize, seed: u64) -> Vec<(Features, f64)> {
        let mut s = seed.wrapping_mul(2).wrapping_add(1);
        let mut next = move || {
            // SplitMix64-style scramble, plenty for test data.
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            (z ^ (z >> 31)) as f64 / u64::MAX as f64
        };
        (0..n)
            .map(|i| {
                let a = next() * 2.0 - 1.0;
                let b = next() * 2.0 - 1.0;
                let y = if a + b >= 0.0 { 1.0 } else { -1.0 };
                let (i0, i1) = ((i % 3) as u32, 3 + (i % 5) as u32);
                let _ = dim;
                (Features::Sparse { idx: vec![i0, i1], val: vec![a, b] }, y)
            })
            .collect()
    }

    #[test]
    fn same_seed_matches_offline_run() {
        let cfg = test_cfg();
        let dim = 16;
        let examples = stream(300, dim, 5);

        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        for (x, y) in &examples {
            // The queue outruns the feeder here only if the OS starves
            // the consumer; retry instead of flaking.
            loop {
                match trainer.learn(x.clone(), *y) {
                    Ok(_) => break,
                    Err(LearnError::Shed) => std::thread::yield_now(),
                    Err(LearnError::Closed) => panic!("trainer closed early"),
                }
            }
        }
        trainer.shutdown();

        let mut offline = build_wire_pegasos(&cfg, dim);
        for (x, y) in &examples {
            offline.process(&x.densify(dim), *y);
        }
        let expect = ModelSnapshot::from_trained(&mut offline, cfg.boundary.clone(), cfg.policy);

        let published = published.lock().unwrap();
        let last = published.last().expect("shutdown publishes the final snapshot");
        assert_eq!(last.weights, expect.weights, "same seed ⇒ same weights");
        assert_eq!(last.var_sn, expect.var_sn);
        let snap = trainer.stats();
        assert_eq!(snap.examples, examples.len() as u64);
        assert!(snap.updates > 0);
        assert!(snap.features > 0);
    }

    #[test]
    fn publishes_every_k_updates() {
        let cfg = TrainerWireConfig { publish_every_updates: 5, ..test_cfg() };
        let dim = 8;
        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        // Alternating labels on one fixed coordinate keep the running
        // margin at or below zero on every example (the weight chases
        // the flipping label), so each of the 23 examples is a
        // guaranteed update — no dependence on the stochastic walk.
        for i in 0..23 {
            let y = if i % 2 == 0 { 1.0 } else { -1.0 };
            let x = Features::Sparse { idx: vec![0], val: vec![1.0] };
            while trainer.learn(x.clone(), y) == Err(LearnError::Shed) {
                std::thread::yield_now();
            }
        }
        trainer.shutdown();
        let n = published.lock().unwrap().len();
        // 23 updates at K=5 ⇒ 4 cadence publishes + 1 final partial.
        assert_eq!(trainer.stats().updates, 23);
        assert_eq!(n, 5);
        assert_eq!(trainer.stats().publishes, 5);
    }

    #[test]
    fn full_queue_sheds_explicitly() {
        let cfg = TrainerWireConfig { queue: 2, publish_every_updates: 1, ..test_cfg() };
        let dim = 4;
        // A sink that parks the trainer thread so the queue backs up
        // deterministically: signal entry, then wait for release.
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let sink: PublishSink = Box::new(move |_snap| {
            let _ = entered_tx.send(());
            let _ = release_rx.recv();
            true
        });
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);

        let x = || Features::Sparse { idx: vec![0], val: vec![1.0] };
        // First example updates (margin 0 < θ) and triggers a publish,
        // parking the thread inside the sink.
        trainer.learn(x(), 1.0).unwrap();
        entered_rx.recv().unwrap();
        // Queue is empty and the consumer is parked: fill it, then the
        // next submission must shed.
        trainer.learn(x(), 1.0).unwrap();
        trainer.learn(x(), -1.0).unwrap();
        assert_eq!(trainer.learn(x(), 1.0), Err(LearnError::Shed));
        assert_eq!(trainer.stats().sheds, 1);
        assert_eq!(trainer.stats().examples, 3);

        drop(release_tx); // unpark: further publishes return immediately
        trainer.shutdown();
        assert_eq!(trainer.learn(x(), 1.0), Err(LearnError::Closed));
    }

    #[test]
    fn spawn_warm_starts_from_the_hub_snapshot() {
        let cfg = TrainerWireConfig { publish_every_updates: 1, ..test_cfg() };
        let dim = 4;
        let base = ModelSnapshot {
            weights: vec![0.5, -0.25, 0.0, 0.0],
            var_sn: 1.0,
            boundary: cfg.boundary.clone(),
            policy: cfg.policy,
        };
        let hub = Arc::new(ModelHub::new(base, 4, 64, 1, 0));
        let trainer = OnlineTrainer::spawn(Arc::clone(&hub), &cfg, dim);
        // Margin 0 on an untouched coordinate forces an update; with
        // K=1 that update publishes straight into the hub.
        trainer.learn(Features::Sparse { idx: vec![2], val: vec![1.0] }, 1.0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while trainer.stats().publishes == 0 {
            assert!(Instant::now() < deadline, "publish never fired");
            std::thread::yield_now();
        }
        trainer.shutdown();
        match &*hub.serving_model() {
            ServingModel::Binary(s) => {
                // A cold-started trainer's first update erases the prior
                // weights (decay 1 − 1/t is 0 at t = 1); the warm start
                // advances the step clock, so they survive, only damped.
                assert!(
                    s.weights[0] > 0.0 && s.weights[1] < 0.0,
                    "warm-started weights must survive the first update: {:?}",
                    s.weights
                );
                assert!(s.weights[2] > 0.0, "the update itself must land");
            }
            other => panic!("expected binary serving model, got {}", other.kind_name()),
        }
    }

    #[test]
    fn time_cadence_publishes_without_updates_pending() {
        let cfg = TrainerWireConfig {
            publish_every_updates: 0,
            publish_every_ms: 20,
            ..test_cfg()
        };
        let dim = 4;
        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        trainer.learn(Features::Sparse { idx: vec![0], val: vec![1.0] }, 1.0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while published.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "time-based publish never fired");
            std::thread::sleep(Duration::from_millis(5));
        }
        trainer.shutdown();
    }
}
