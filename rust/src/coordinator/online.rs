//! Online-learning subsystem behind the wire: the `learn` op's engine.
//!
//! One [`OnlineTrainer`] per registry shard owns a live attentive
//! Pegasos ([`crate::learner::pegasos::BoundedPegasos`], built via
//! [`crate::coordinator::factory::build_wire_pegasos`]) on a background
//! thread. Labeled examples arrive through a bounded MPSC queue —
//! enqueue never blocks the wire: when the queue is full the example is
//! *shed* with an explicit, retryable ack, mirroring the score path's
//! admission control. The thread densifies each example, runs one
//! attentive `process` step (spending O(√n) features on easy examples,
//! per the paper), and periodically publishes an immutable
//! [`ModelSnapshot`] into the shard's [`ModelHub`] generation swap:
//! after every K updates and/or T milliseconds, whichever fires first.
//! Concurrent `score`/`classify` traffic picks up the new generation
//! through the hub's existing swap — zero added cost on the scoring hot
//! path.
//!
//! Determinism: a single consumer thread processes examples in queue
//! order with a config-seeded learner, so the same accepted sequence
//! reproduces the same weights as an offline run (tested below).

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TrainerWireConfig;
use crate::coordinator::factory::build_wire_pegasos;
use crate::coordinator::service::{Features, ModelSnapshot, ServingModel};
use crate::learner::OnlineLearner;
use crate::server::faultpoint;
use crate::server::hub::ModelHub;
use crate::util::json::Json;

/// Poll interval when no time-based publish is pending — only bounds
/// how quickly the thread notices a dropped sender, not learn latency.
const IDLE_POLL_MS: u64 = 250;

/// One labeled example bound for a shard's trainer.
#[derive(Debug, Clone)]
pub struct LearnExample {
    /// Feature vector (sparse or dense).
    pub features: Features,
    /// Label, ±1.
    pub label: f64,
}

/// Why a `learn` submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnError {
    /// The bounded learn queue is full; the example was shed. Retryable.
    Shed,
    /// The trainer has shut down.
    Closed,
}

/// Live counters for one shard's trainer. `examples` counts accepted
/// (enqueued) submissions; `updates`/`features` are bumped by the
/// trainer thread as it processes; `sheds` counts queue-full rejects;
/// `publishes` counts snapshot generations pushed into the hub.
#[derive(Debug, Default)]
pub struct TrainerStats {
    /// Examples accepted into the queue.
    pub examples: AtomicU64,
    /// Model updates applied.
    pub updates: AtomicU64,
    /// Examples shed on queue overflow.
    pub sheds: AtomicU64,
    /// Snapshots published into the hub.
    pub publishes: AtomicU64,
    /// Feature evaluations spent while learning (the paper's budget
    /// axis: sub-linear per example when the attentive boundary fires).
    pub features: AtomicU64,
}

/// A point-in-time copy of [`TrainerStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrainerStatsSnapshot {
    /// Examples accepted into the queue.
    pub examples: u64,
    /// Model updates applied.
    pub updates: u64,
    /// Examples shed on queue overflow.
    pub sheds: u64,
    /// Snapshots published into the hub.
    pub publishes: u64,
    /// Feature evaluations spent while learning.
    pub features: u64,
}

impl TrainerStats {
    /// Copy the counters (relaxed: monotone counters, not an invariant).
    pub fn snapshot(&self) -> TrainerStatsSnapshot {
        TrainerStatsSnapshot {
            examples: self.examples.load(Ordering::Relaxed),
            updates: self.updates.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            features: self.features.load(Ordering::Relaxed),
        }
    }
}

/// Where published snapshots go. Production is a [`ModelHub`] reload;
/// tests capture snapshots directly. Returns whether the publish stuck.
pub type PublishSink = Box<dyn FnMut(ModelSnapshot) -> bool + Send>;

/// Handle to one shard's background trainer thread. Shared behind the
/// registry (`&self` API); shutdown is idempotent and joins the thread.
pub struct OnlineTrainer {
    tx: Mutex<Option<SyncSender<LearnExample>>>,
    join: Mutex<Option<JoinHandle<()>>>,
    stats: Arc<TrainerStats>,
}

impl std::fmt::Debug for OnlineTrainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OnlineTrainer").field("stats", &self.stats.snapshot()).finish()
    }
}

impl OnlineTrainer {
    /// Spawn a trainer publishing into `hub`'s generation swap. If the
    /// shard currently serves a binary model with trained (nonzero)
    /// weights, the trainer **warm-starts** from that snapshot — weights,
    /// Pegasos step clock, and variance prior — instead of `w = 0`, so
    /// attaching a trainer to a loaded shard is immediately incremental
    /// rather than relearning from scratch.
    pub fn spawn(hub: Arc<ModelHub>, cfg: &TrainerWireConfig, dim: usize) -> Self {
        let init = match &*hub.serving_model() {
            ServingModel::Binary(snap) => Some(snap.clone()),
            _ => None,
        };
        Self::spawn_inner(cfg, dim, init, Box::new(move |snap| hub.reload(snap).is_ok()))
    }

    /// Spawn a trainer publishing into an arbitrary sink (tests, tools).
    /// Always cold-starts from `w = 0`.
    pub fn spawn_with_sink(cfg: &TrainerWireConfig, dim: usize, sink: PublishSink) -> Self {
        Self::spawn_inner(cfg, dim, None, sink)
    }

    /// Like [`OnlineTrainer::spawn`], but every successfully published
    /// generation is also persisted into `store` (atomic write: temp
    /// file + fsync + rename). Persist happens *before* the hub swap,
    /// so a crash immediately after clients observe a generation can
    /// never leave that generation unrecoverable. The trainer's final
    /// shutdown publish rides the same sink, giving the "final persist
    /// on shutdown" guarantee for free. A persist failure is logged and
    /// does not block serving — the previous generation on disk remains
    /// the recovery point.
    pub fn spawn_with_store(
        hub: Arc<ModelHub>,
        cfg: &TrainerWireConfig,
        dim: usize,
        store: SnapshotStore,
    ) -> Self {
        let init = match &*hub.serving_model() {
            ServingModel::Binary(snap) => Some(snap.clone()),
            _ => None,
        };
        Self::spawn_inner(
            cfg,
            dim,
            init,
            Box::new(move |snap| {
                if let Err(e) = store.persist(&snap) {
                    eprintln!("warning: snapshot persist failed in {}: {e}", store.dir().display());
                }
                hub.reload(snap).is_ok()
            }),
        )
    }

    fn spawn_inner(
        cfg: &TrainerWireConfig,
        dim: usize,
        init: Option<ModelSnapshot>,
        sink: PublishSink,
    ) -> Self {
        let (tx, rx) = sync_channel(cfg.queue.max(1));
        let stats = Arc::new(TrainerStats::default());
        let thread_stats = Arc::clone(&stats);
        let cfg = cfg.clone();
        let join = std::thread::Builder::new()
            .name("online-trainer".into())
            .spawn(move || run_trainer(rx, cfg, dim, init, thread_stats, sink))
            .expect("spawn online trainer thread");
        Self { tx: Mutex::new(Some(tx)), join: Mutex::new(Some(join)), stats }
    }

    /// Submit one labeled example without blocking. On success returns
    /// the cumulative accepted-example count (for the wire ack); a full
    /// queue sheds the example and reports [`LearnError::Shed`].
    pub fn learn(&self, features: Features, label: f64) -> Result<u64, LearnError> {
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref().ok_or(LearnError::Closed)?;
        match tx.try_send(LearnExample { features, label }) {
            Ok(()) => Ok(self.stats.examples.fetch_add(1, Ordering::Relaxed) + 1),
            Err(TrySendError::Full(_)) => {
                self.stats.sheds.fetch_add(1, Ordering::Relaxed);
                Err(LearnError::Shed)
            }
            Err(TrySendError::Disconnected(_)) => Err(LearnError::Closed),
        }
    }

    /// Live counters.
    pub fn stats(&self) -> TrainerStatsSnapshot {
        self.stats.snapshot()
    }

    /// Drop the queue and join the thread, which drains remaining
    /// examples and publishes a final snapshot if anything changed
    /// since the last publish. Idempotent.
    pub fn shutdown(&self) {
        drop(self.tx.lock().unwrap().take());
        if let Some(join) = self.join.lock().unwrap().take() {
            let _ = join.join();
        }
    }
}

impl Drop for OnlineTrainer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// File magic for persisted snapshots ("Attentive SNaPshot").
const SNAP_MAGIC: &[u8; 4] = b"ASNP";
/// Header: magic (4) + format version u32 LE (4) + payload length
/// u32 LE (4) + FNV-1a-64 checksum of the payload u64 LE (8).
const SNAP_HEADER_LEN: usize = 20;
/// Persisted-format version; bump on any layout change.
const SNAP_VERSION: u32 = 1;
/// Generations kept on disk per shard; older ones are pruned after
/// each successful persist.
const SNAP_KEEP: usize = 8;

/// FNV-1a 64-bit — tiny, std-only, and plenty to catch torn writes.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Durable, crash-safe storage for one shard's published
/// [`ModelSnapshot`] generations.
///
/// Layout: one file per generation, `gen-<n, zero-padded to 20>.snap`,
/// so lexicographic filename order *is* numeric generation order. Each
/// file is a 20-byte header (magic + version + payload length + FNV-1a
/// checksum) followed by the snapshot's compact-JSON payload. Writes go
/// through a temp file in the same directory, `fsync`, `rename`, then a
/// directory fsync — a crash at any point leaves either the old state
/// or the new state, never a half-file under the final name. Recovery
/// ([`SnapshotStore::load_newest`]) walks generations newest-first and
/// skips any file whose header, length, or checksum doesn't verify, so
/// a torn write (e.g. power loss mid-`write`, or the injected
/// `snapshot-fail` fault) silently falls back to the previous good
/// generation.
///
/// The generation counter is seeded past the newest on-disk generation
/// at open, keeping generations monotonic across process restarts.
pub struct SnapshotStore {
    dir: PathBuf,
    next_gen: AtomicU64,
}

impl std::fmt::Debug for SnapshotStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotStore")
            .field("dir", &self.dir)
            .field("next_gen", &self.next_gen.load(Ordering::Relaxed))
            .finish()
    }
}

impl SnapshotStore {
    /// Open (creating if needed) the store rooted at `dir`. Leftover
    /// temp files from an interrupted write are removed; the generation
    /// counter resumes after the newest file present, valid or not —
    /// a torn generation's number is burned, never reused.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let mut max_gen = 0u64;
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with(".tmp-") {
                let _ = std::fs::remove_file(entry.path());
            } else if let Some(gen) = parse_gen(&name) {
                max_gen = max_gen.max(gen);
            }
        }
        Ok(Self { dir, next_gen: AtomicU64::new(max_gen + 1) })
    }

    /// The directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist one snapshot as the next generation, atomically, and
    /// prune generations beyond the newest [`SNAP_KEEP`]. Returns the
    /// generation number written.
    pub fn persist(&self, snap: &ModelSnapshot) -> std::io::Result<u64> {
        let gen = self.next_gen.fetch_add(1, Ordering::Relaxed);
        let payload = snap.to_json().to_string_compact().into_bytes();
        let mut bytes = Vec::with_capacity(SNAP_HEADER_LEN + payload.len());
        bytes.extend_from_slice(SNAP_MAGIC);
        bytes.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);

        let final_path = self.dir.join(gen_name(gen));
        if faultpoint::fires(faultpoint::Point::SnapshotFail) {
            // Crash emulation: the final name appears holding only a
            // prefix of the bytes — what a power cut mid-write (with no
            // temp/rename discipline) would leave. Recovery must skip it.
            let torn = &bytes[..bytes.len() / 2];
            std::fs::write(&final_path, torn)?;
            return Err(std::io::Error::other("injected fault: snapshot-fail (torn file)"));
        }

        let tmp_path = self.dir.join(format!(".tmp-{}", gen_name(gen)));
        {
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &final_path)?;
        // Make the rename itself durable: fsync the directory entry.
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.prune();
        Ok(gen)
    }

    /// Load the newest on-disk generation that verifies (magic, version,
    /// length, checksum, JSON parse). Truncated or corrupt files are
    /// skipped in favor of the previous generation. Returns the
    /// generation number with the snapshot, or `None` if nothing valid
    /// is present.
    pub fn load_newest(&self) -> Option<(u64, ModelSnapshot)> {
        let mut gens = self.list_gens();
        gens.sort_unstable_by(|a, b| b.cmp(a));
        for gen in gens {
            if let Some(snap) = read_validated(&self.dir.join(gen_name(gen))) {
                return Some((gen, snap));
            }
        }
        None
    }

    /// Generation numbers currently on disk, unsorted.
    fn list_gens(&self) -> Vec<u64> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return Vec::new() };
        entries
            .flatten()
            .filter_map(|e| parse_gen(&e.file_name().to_string_lossy()))
            .collect()
    }

    /// Delete all but the newest [`SNAP_KEEP`] generations. Best-effort:
    /// a failed unlink only means extra files linger.
    fn prune(&self) {
        let mut gens = self.list_gens();
        if gens.len() <= SNAP_KEEP {
            return;
        }
        gens.sort_unstable();
        for gen in &gens[..gens.len() - SNAP_KEEP] {
            let _ = std::fs::remove_file(self.dir.join(gen_name(*gen)));
        }
    }
}

/// `gen-<zero-padded-20>.snap`: lexicographic order == numeric order.
fn gen_name(gen: u64) -> String {
    format!("gen-{gen:020}.snap")
}

/// Inverse of [`gen_name`]; `None` for foreign files.
fn parse_gen(name: &str) -> Option<u64> {
    name.strip_prefix("gen-")?.strip_suffix(".snap")?.parse().ok()
}

/// Read and fully verify one snapshot file; `None` on any mismatch.
fn read_validated(path: &Path) -> Option<ModelSnapshot> {
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() < SNAP_HEADER_LEN || &bytes[..4] != SNAP_MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != SNAP_VERSION {
        return None;
    }
    let len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let checksum = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let payload = &bytes[SNAP_HEADER_LEN..];
    if payload.len() != len || fnv1a(payload) != checksum {
        return None;
    }
    let text = std::str::from_utf8(payload).ok()?;
    let json = Json::parse(text).ok()?;
    ModelSnapshot::from_json(&json).ok()
}

/// The trainer thread: consume → densify → attentive step → publish on
/// K updates and/or T ms, whichever first; final publish on shutdown.
fn run_trainer(
    rx: Receiver<LearnExample>,
    cfg: TrainerWireConfig,
    dim: usize,
    init: Option<ModelSnapshot>,
    stats: Arc<TrainerStats>,
    mut sink: PublishSink,
) {
    let mut learner = build_wire_pegasos(&cfg, dim);
    if let Some(snap) = init {
        // No-op on zero or malformed snapshots, so a freshly provisioned
        // shard still trains exactly like an offline from-zero run.
        learner.warm_start(&snap.weights, snap.var_sn);
    }
    let mut updates_since_publish = 0u64;
    let mut dirty = false;
    let mut last_publish = Instant::now();
    let time_cadence =
        (cfg.publish_every_ms > 0).then(|| Duration::from_millis(cfg.publish_every_ms));

    let mut publish = |learner: &mut _, dirty: &mut bool, updates: &mut u64, last: &mut Instant| {
        let snap = ModelSnapshot::from_trained(learner, cfg.boundary.clone(), cfg.policy);
        if sink(snap) {
            stats.publishes.fetch_add(1, Ordering::Relaxed);
        }
        *dirty = false;
        *updates = 0;
        *last = Instant::now();
    };

    loop {
        if dirty {
            if let Some(t) = time_cadence {
                if last_publish.elapsed() >= t {
                    publish(&mut learner, &mut dirty, &mut updates_since_publish, &mut last_publish);
                }
            }
        }
        let timeout = match (dirty, time_cadence) {
            (true, Some(t)) => t.saturating_sub(last_publish.elapsed()),
            _ => Duration::from_millis(IDLE_POLL_MS),
        };
        match rx.recv_timeout(timeout) {
            Ok(ex) => {
                let x = ex.features.densify(dim);
                let info = learner.process(&x, ex.label);
                stats.features.fetch_add(info.evaluated as u64, Ordering::Relaxed);
                dirty = true;
                if info.updated {
                    stats.updates.fetch_add(1, Ordering::Relaxed);
                    updates_since_publish += 1;
                    if cfg.publish_every_updates > 0
                        && updates_since_publish >= cfg.publish_every_updates
                    {
                        publish(
                            &mut learner,
                            &mut dirty,
                            &mut updates_since_publish,
                            &mut last_publish,
                        );
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if dirty {
                    publish(&mut learner, &mut dirty, &mut updates_since_publish, &mut last_publish);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn test_cfg() -> TrainerWireConfig {
        TrainerWireConfig {
            queue: 64,
            publish_every_updates: 0,
            publish_every_ms: 0, // direct spawns skip validate(); shutdown publishes
            lambda: 1e-2,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::WeightSampled,
            seed: 11,
            ..Default::default()
        }
    }

    fn capture_sink() -> (Arc<Mutex<Vec<ModelSnapshot>>>, PublishSink) {
        let published = Arc::new(Mutex::new(Vec::new()));
        let sink_ref = Arc::clone(&published);
        let sink: PublishSink = Box::new(move |snap| {
            sink_ref.lock().unwrap().push(snap);
            true
        });
        (published, sink)
    }

    /// Synthetic separable stream: sign of the sum of two informative
    /// coordinates, embedded sparsely in `dim`.
    fn stream(n: usize, dim: usize, seed: u64) -> Vec<(Features, f64)> {
        let mut s = seed.wrapping_mul(2).wrapping_add(1);
        let mut next = move || {
            // SplitMix64-style scramble, plenty for test data.
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            (z ^ (z >> 31)) as f64 / u64::MAX as f64
        };
        (0..n)
            .map(|i| {
                let a = next() * 2.0 - 1.0;
                let b = next() * 2.0 - 1.0;
                let y = if a + b >= 0.0 { 1.0 } else { -1.0 };
                let (i0, i1) = ((i % 3) as u32, 3 + (i % 5) as u32);
                let _ = dim;
                (Features::Sparse { idx: vec![i0, i1], val: vec![a, b] }, y)
            })
            .collect()
    }

    #[test]
    fn same_seed_matches_offline_run() {
        let cfg = test_cfg();
        let dim = 16;
        let examples = stream(300, dim, 5);

        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        for (x, y) in &examples {
            // The queue outruns the feeder here only if the OS starves
            // the consumer; retry instead of flaking.
            loop {
                match trainer.learn(x.clone(), *y) {
                    Ok(_) => break,
                    Err(LearnError::Shed) => std::thread::yield_now(),
                    Err(LearnError::Closed) => panic!("trainer closed early"),
                }
            }
        }
        trainer.shutdown();

        let mut offline = build_wire_pegasos(&cfg, dim);
        for (x, y) in &examples {
            offline.process(&x.densify(dim), *y);
        }
        let expect = ModelSnapshot::from_trained(&mut offline, cfg.boundary.clone(), cfg.policy);

        let published = published.lock().unwrap();
        let last = published.last().expect("shutdown publishes the final snapshot");
        assert_eq!(last.weights, expect.weights, "same seed ⇒ same weights");
        assert_eq!(last.var_sn, expect.var_sn);
        let snap = trainer.stats();
        assert_eq!(snap.examples, examples.len() as u64);
        assert!(snap.updates > 0);
        assert!(snap.features > 0);
    }

    #[test]
    fn publishes_every_k_updates() {
        let cfg = TrainerWireConfig { publish_every_updates: 5, ..test_cfg() };
        let dim = 8;
        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        // Alternating labels on one fixed coordinate keep the running
        // margin at or below zero on every example (the weight chases
        // the flipping label), so each of the 23 examples is a
        // guaranteed update — no dependence on the stochastic walk.
        for i in 0..23 {
            let y = if i % 2 == 0 { 1.0 } else { -1.0 };
            let x = Features::Sparse { idx: vec![0], val: vec![1.0] };
            while trainer.learn(x.clone(), y) == Err(LearnError::Shed) {
                std::thread::yield_now();
            }
        }
        trainer.shutdown();
        let n = published.lock().unwrap().len();
        // 23 updates at K=5 ⇒ 4 cadence publishes + 1 final partial.
        assert_eq!(trainer.stats().updates, 23);
        assert_eq!(n, 5);
        assert_eq!(trainer.stats().publishes, 5);
    }

    #[test]
    fn full_queue_sheds_explicitly() {
        let cfg = TrainerWireConfig { queue: 2, publish_every_updates: 1, ..test_cfg() };
        let dim = 4;
        // A sink that parks the trainer thread so the queue backs up
        // deterministically: signal entry, then wait for release.
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let sink: PublishSink = Box::new(move |_snap| {
            let _ = entered_tx.send(());
            let _ = release_rx.recv();
            true
        });
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);

        let x = || Features::Sparse { idx: vec![0], val: vec![1.0] };
        // First example updates (margin 0 < θ) and triggers a publish,
        // parking the thread inside the sink.
        trainer.learn(x(), 1.0).unwrap();
        entered_rx.recv().unwrap();
        // Queue is empty and the consumer is parked: fill it, then the
        // next submission must shed.
        trainer.learn(x(), 1.0).unwrap();
        trainer.learn(x(), -1.0).unwrap();
        assert_eq!(trainer.learn(x(), 1.0), Err(LearnError::Shed));
        assert_eq!(trainer.stats().sheds, 1);
        assert_eq!(trainer.stats().examples, 3);

        drop(release_tx); // unpark: further publishes return immediately
        trainer.shutdown();
        assert_eq!(trainer.learn(x(), 1.0), Err(LearnError::Closed));
    }

    #[test]
    fn spawn_warm_starts_from_the_hub_snapshot() {
        let cfg = TrainerWireConfig { publish_every_updates: 1, ..test_cfg() };
        let dim = 4;
        let base = ModelSnapshot {
            weights: vec![0.5, -0.25, 0.0, 0.0],
            var_sn: 1.0,
            boundary: cfg.boundary.clone(),
            policy: cfg.policy,
        };
        let hub = Arc::new(ModelHub::new(base, 4, 64, 1, 0));
        let trainer = OnlineTrainer::spawn(Arc::clone(&hub), &cfg, dim);
        // Margin 0 on an untouched coordinate forces an update; with
        // K=1 that update publishes straight into the hub.
        trainer.learn(Features::Sparse { idx: vec![2], val: vec![1.0] }, 1.0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while trainer.stats().publishes == 0 {
            assert!(Instant::now() < deadline, "publish never fired");
            std::thread::yield_now();
        }
        trainer.shutdown();
        match &*hub.serving_model() {
            ServingModel::Binary(s) => {
                // A cold-started trainer's first update erases the prior
                // weights (decay 1 − 1/t is 0 at t = 1); the warm start
                // advances the step clock, so they survive, only damped.
                assert!(
                    s.weights[0] > 0.0 && s.weights[1] < 0.0,
                    "warm-started weights must survive the first update: {:?}",
                    s.weights
                );
                assert!(s.weights[2] > 0.0, "the update itself must land");
            }
            other => panic!("expected binary serving model, got {}", other.kind_name()),
        }
    }

    /// Self-cleaning unique temp dir for store tests (std-only).
    struct TempDir(PathBuf);
    impl TempDir {
        fn new(tag: &str) -> Self {
            static SEQ: AtomicU64 = AtomicU64::new(0);
            let n = SEQ.fetch_add(1, Ordering::Relaxed);
            let dir = std::env::temp_dir().join(format!(
                "attentive-snap-{tag}-{}-{n}",
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            Self(dir)
        }
    }
    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn snap_with_weights(w: Vec<f64>) -> ModelSnapshot {
        ModelSnapshot {
            weights: w,
            var_sn: 2.5,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::WeightSampled,
        }
    }

    #[test]
    fn snapshot_store_round_trips_bit_identical() {
        let tmp = TempDir::new("rt");
        let store = SnapshotStore::open(&tmp.0).unwrap();
        let snap = snap_with_weights(vec![0.125, -3.5, 0.0, 1e-9]);
        let gen = store.persist(&snap).unwrap();
        assert_eq!(gen, 1);
        let (got_gen, got) = store.load_newest().expect("persisted snapshot loads back");
        assert_eq!(got_gen, 1);
        // Weights survive the JSON round trip bit-identical: the
        // serializer prints shortest-round-trip floats.
        assert_eq!(got.weights, snap.weights);
        assert_eq!(got.var_sn, snap.var_sn);
    }

    #[test]
    fn truncated_newest_falls_back_to_previous_generation() {
        let tmp = TempDir::new("trunc");
        let store = SnapshotStore::open(&tmp.0).unwrap();
        store.persist(&snap_with_weights(vec![1.0, 2.0])).unwrap();
        let gen2 = store.persist(&snap_with_weights(vec![3.0, 4.0])).unwrap();
        // Tear the newest file in half, as a crash mid-write would.
        let path = tmp.0.join(format!("gen-{gen2:020}.snap"));
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let (gen, snap) = store.load_newest().expect("previous generation survives");
        assert_eq!(gen, 1, "torn newest must be skipped");
        assert_eq!(snap.weights, vec![1.0, 2.0]);
        // A checksum-flip (right length, wrong bytes) is also rejected.
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        assert_eq!(store.load_newest().unwrap().0, 1, "corrupt payload must be skipped");
    }

    #[test]
    fn generations_stay_monotonic_across_reopen_and_prune_keeps_newest() {
        let tmp = TempDir::new("gens");
        {
            let store = SnapshotStore::open(&tmp.0).unwrap();
            for i in 0..3 {
                store.persist(&snap_with_weights(vec![i as f64])).unwrap();
            }
        }
        // Reopen: the counter resumes after the newest on-disk file.
        let store = SnapshotStore::open(&tmp.0).unwrap();
        assert_eq!(store.persist(&snap_with_weights(vec![9.0])).unwrap(), 4);
        for i in 0..SNAP_KEEP as u64 + 3 {
            store.persist(&snap_with_weights(vec![100.0 + i as f64])).unwrap();
        }
        let gens: Vec<u64> = {
            let mut g = store.list_gens();
            g.sort_unstable();
            g
        };
        assert_eq!(gens.len(), SNAP_KEEP, "prune keeps exactly the newest {SNAP_KEEP}");
        assert!(gens.windows(2).all(|w| w[1] == w[0] + 1), "kept set is contiguous: {gens:?}");
        let (newest, snap) = store.load_newest().unwrap();
        assert_eq!(newest, *gens.last().unwrap());
        assert_eq!(snap.weights, vec![100.0 + (SNAP_KEEP as f64 + 2.0)]);
    }

    #[test]
    fn generations_monotonic_across_two_crash_restart_cycles() {
        let tmp = TempDir::new("crash2");
        let tear = |gen: u64| {
            // Crash emulation, as the snapshot-fail point does it: the
            // final name appears holding only a prefix of valid bytes.
            let donor = std::fs::read(tmp.0.join(gen_name(gen - 1))).unwrap();
            std::fs::write(tmp.0.join(gen_name(gen)), &donor[..donor.len() / 2]).unwrap();
        };

        // Cycle 1: two clean generations, then a crash mid-write of the
        // third — a torn gen-3 lands on disk, plus a stray temp file.
        {
            let store = SnapshotStore::open(&tmp.0).unwrap();
            assert_eq!(store.persist(&snap_with_weights(vec![1.0])).unwrap(), 1);
            assert_eq!(store.persist(&snap_with_weights(vec![2.0])).unwrap(), 2);
            tear(3);
            std::fs::write(tmp.0.join(format!(".tmp-{}", gen_name(3))), b"partial").unwrap();
        }
        // Restart 1: the temp file is swept, the torn generation's
        // number is burned (never reused), recovery serves gen 2.
        {
            let store = SnapshotStore::open(&tmp.0).unwrap();
            let (gen, snap) = store.load_newest().expect("gen 2 survives the crash");
            assert_eq!(gen, 2);
            assert_eq!(snap.weights, vec![2.0]);
            assert_eq!(store.persist(&snap_with_weights(vec![4.0])).unwrap(), 4);
            assert!(!tmp.0.join(format!(".tmp-{}", gen_name(3))).exists());
            // Cycle 2: crash again, mid-write of gen 5.
            tear(5);
        }
        // Restart 2: same contract, one more generation forward.
        let store = SnapshotStore::open(&tmp.0).unwrap();
        let (gen, snap) = store.load_newest().expect("gen 4 survives the second crash");
        assert_eq!(gen, 4);
        assert_eq!(snap.weights, vec![4.0]);
        assert_eq!(store.persist(&snap_with_weights(vec![6.0])).unwrap(), 6);
        // The generation sequence only ever moved forward: across both
        // crash/restart cycles every write got a fresh number, and the
        // newest valid snapshot is the last clean write.
        let mut gens = store.list_gens();
        gens.sort_unstable();
        assert_eq!(gens, vec![1, 2, 3, 4, 5, 6], "torn numbers burned, none reused");
        assert_eq!(store.load_newest().unwrap().0, 6);
    }

    #[test]
    fn spawn_with_store_persists_published_generations() {
        let tmp = TempDir::new("spawn");
        let cfg = TrainerWireConfig { publish_every_updates: 1, ..test_cfg() };
        let dim = 4;
        let base = snap_with_weights(vec![0.0; 4]);
        let hub = Arc::new(ModelHub::new(base, 4, 64, 1, 0));
        let store = SnapshotStore::open(&tmp.0).unwrap();
        let trainer = OnlineTrainer::spawn_with_store(Arc::clone(&hub), &cfg, dim, store);
        trainer.learn(Features::Sparse { idx: vec![1], val: vec![1.0] }, 1.0).unwrap();
        trainer.shutdown();
        // Reopen the directory independently: the published generation
        // must be on disk and identical to what the hub now serves.
        let store = SnapshotStore::open(&tmp.0).unwrap();
        let (_, recovered) = store.load_newest().expect("trainer persisted its publish");
        match &*hub.serving_model() {
            ServingModel::Binary(s) => {
                assert_eq!(recovered.weights, s.weights, "disk matches the serving generation");
                assert_eq!(recovered.var_sn, s.var_sn);
            }
            other => panic!("expected binary serving model, got {}", other.kind_name()),
        }
    }

    #[test]
    fn time_cadence_publishes_without_updates_pending() {
        let cfg = TrainerWireConfig {
            publish_every_updates: 0,
            publish_every_ms: 20,
            ..test_cfg()
        };
        let dim = 4;
        let (published, sink) = capture_sink();
        let trainer = OnlineTrainer::spawn_with_sink(&cfg, dim, sink);
        trainer.learn(Features::Sparse { idx: vec![0], val: vec![1.0] }, 1.0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while published.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "time-based publish never fired");
            std::thread::sleep(Duration::from_millis(5));
        }
        trainer.shutdown();
    }
}
