//! Threaded prediction service with attentive early-exit.
//!
//! A model-server-style serving loop: requests (feature vectors) arrive
//! on an mpsc queue, worker threads drain up to `max_batch` requests at a
//! time (dynamic batching without a timer: lowest latency at low load,
//! full batches under pressure), and each example is scored with the
//! **early-stopped predictor** — easy inputs exit after a handful of
//! features, hard ones get the full evaluation. The paper's
//! focus-of-attention becomes a serving-latency mechanism: average
//! feature cost (≈ service time) scales with input difficulty, not
//! dimensionality.
//!
//! Python is never involved: the model is a plain weight vector (trained
//! by the coordinator or loaded from a JSON snapshot) and the hot loop is
//! pure rust.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::BrownoutConfig;
use crate::learner::predictor::TabledPredictor;
use crate::margin::policy::{CoordinatePolicy, OrderGenerator};
use crate::stst::boundary::{AnyBoundary, TableCache};
use crate::util::json::Json;

/// Immutable model snapshot served by the service.
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    /// Weight vector.
    pub weights: Vec<f64>,
    /// Full-sum variance estimate used by the prediction boundary
    /// (max over the two classes, conservative).
    pub var_sn: f64,
    /// Boundary the service applies at prediction time.
    pub boundary: AnyBoundary,
    /// Coordinate policy for the prediction walks.
    pub policy: CoordinatePolicy,
}

impl ModelSnapshot {
    /// Snapshot a trained Pegasos-family learner for serving: its weight
    /// vector, a conservative `var(S_n)` estimate (max over the two
    /// labels), and the given prediction-time boundary and policy. The
    /// single source of the subtle two-label variance step, shared by the
    /// CLI, benches, examples, and tests.
    pub fn from_trained<B: crate::stst::boundary::Boundary>(
        learner: &mut crate::learner::pegasos::BoundedPegasos<B>,
        boundary: AnyBoundary,
        policy: CoordinatePolicy,
    ) -> Self {
        use crate::learner::OnlineLearner as _;
        let weights = learner.weights().to_vec();
        let var_sn = {
            let vc = learner.var_cache_mut();
            let a = vc.var_sn(1.0, &weights);
            let b = vc.var_sn(-1.0, &weights);
            a.max(b)
        };
        Self { weights, var_sn, boundary, policy }
    }

    /// Serialize (for `attentive serve --snapshot`).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("weights", Json::Arr(self.weights.iter().map(|&w| Json::Num(w)).collect())),
            ("var_sn", Json::Num(self.var_sn)),
            ("boundary", self.boundary.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
        ])
    }

    /// Parse the form produced by [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<Self, String> {
        Ok(Self {
            weights: v
                .get("weights")
                .and_then(|a| a.as_arr())
                .ok_or("snapshot: missing weights")?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| "snapshot: non-numeric weight".to_string()))
                .collect::<Result<_, _>>()?,
            var_sn: v.get("var_sn").and_then(|x| x.as_f64()).ok_or("snapshot: missing var_sn")?,
            boundary: AnyBoundary::from_json(v.get("boundary").ok_or("snapshot: missing boundary")?)?,
            policy: CoordinatePolicy::from_name(
                v.get("policy").and_then(|s| s.as_str()).ok_or("snapshot: missing policy")?,
            )?,
        })
    }
}

/// One voter of an [`EnsembleSnapshot`]: the binary model for an
/// unordered class pair. A positive margin votes for `pos`.
#[derive(Debug, Clone)]
pub struct VoterSnapshot {
    /// Class a positive margin votes for.
    pub pos: i64,
    /// Class a negative margin votes for.
    pub neg: i64,
    /// Weight vector.
    pub weights: Vec<f64>,
    /// Conservative `var(S_n)` estimate for this voter's boundary.
    pub var_sn: f64,
}

/// Immutable all-pairs (1-vs-1) multiclass ensemble snapshot — the
/// serving counterpart of [`crate::learner::multiclass::OneVsOneEnsemble`],
/// the way [`ModelSnapshot`] is the serving counterpart of a trained
/// binary learner.
///
/// At classification time each of the `C(C-1)/2` voters runs the
/// two-sided early-stopped sign test independently, so the paper's
/// attention mechanism compounds: total feature cost is the sum of
/// per-voter early exits, sub-linear in both support size and voter
/// count touched, not `voters × dim`.
#[derive(Debug, Clone)]
pub struct EnsembleSnapshot {
    /// Classes the ensemble distinguishes, strictly increasing.
    pub classes: Vec<i64>,
    /// Boundary every voter applies at prediction time.
    pub boundary: AnyBoundary,
    /// Coordinate policy for the per-voter prediction walks.
    pub policy: CoordinatePolicy,
    /// One voter per unordered class pair, in enumeration order
    /// (`(classes[a], classes[b])` for `a < b`).
    pub voters: Vec<VoterSnapshot>,
}

impl EnsembleSnapshot {
    /// Snapshot a trained [`OneVsOneEnsemble`] for serving: per voter,
    /// its weight vector and a conservative `var(S_n)` (max over the
    /// two labels), plus the given prediction-time boundary and policy.
    ///
    /// [`OneVsOneEnsemble`]: crate::learner::multiclass::OneVsOneEnsemble
    pub fn from_trained(
        ensemble: &mut crate::learner::multiclass::OneVsOneEnsemble,
        boundary: AnyBoundary,
        policy: CoordinatePolicy,
    ) -> Self {
        use crate::learner::OnlineLearner as _;
        let classes = ensemble.classes().to_vec();
        let mut voters = Vec::with_capacity(ensemble.voter_count());
        for (&(pos, neg), learner) in ensemble.voters_mut() {
            let weights = learner.weights().to_vec();
            let var_sn = {
                let vc = learner.var_cache_mut();
                let a = vc.var_sn(1.0, &weights);
                let b = vc.var_sn(-1.0, &weights);
                a.max(b)
            };
            voters.push(VoterSnapshot { pos, neg, weights, var_sn });
        }
        Self { classes, boundary, policy, voters }
    }

    /// Feature dimensionality (shared by every voter).
    pub fn dim(&self) -> usize {
        self.voters.first().map_or(0, |v| v.weights.len())
    }

    /// Number of binary voters (`C(C-1)/2`).
    pub fn voter_count(&self) -> usize {
        self.voters.len()
    }

    /// Per-worker serving state for [`Self::classify`]: one
    /// coordinate-order generator and one threshold-table cache per
    /// voter (seeded/built independently against that voter's weights
    /// and variance), the precomputed class-slot map for the vote tally,
    /// and the reusable tally buffer. Weights are immutable for the
    /// snapshot's lifetime, so the (possibly O(n log n)) order refresh
    /// and the boundary-table build happen once per worker generation,
    /// not per request.
    pub fn make_scratch(&self, seed: u64) -> ClassifyScratch {
        let orders = self
            .voters
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let salt = (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let mut gen = OrderGenerator::new(self.policy, seed ^ salt);
                gen.refresh(&v.weights);
                gen
            })
            .collect();
        let dim = self.dim();
        let tables = self
            .voters
            .iter()
            .map(|v| TableCache::new(self.boundary.clone(), v.var_sn, dim))
            .collect();
        // Classes are strictly increasing (enforced by from_json), so
        // each voter's (pos, neg) resolves to tally slots up front —
        // the vote loop indexes instead of scanning. A class missing
        // from `classes` (possible only for hand-built snapshots) maps
        // to the out-of-range sentinel and its votes are dropped,
        // matching the old linear scan's behavior.
        let slot = |c: i64| self.classes.binary_search(&c).map_or(u32::MAX, |i| i as u32);
        let pair_slots = self.voters.iter().map(|v| (slot(v.pos), slot(v.neg))).collect();
        ClassifyScratch {
            orders,
            tables,
            pair_slots,
            tally: vec![0; self.classes.len()],
        }
    }

    /// Attentive all-pairs vote: every voter early-exits independently,
    /// votes are tallied, and ties break toward the smaller class label
    /// (deterministic, matching the offline
    /// [`OneVsOneEnsemble::predict`]). `scratch` must come from
    /// [`Self::make_scratch`] on this snapshot. The response's `score`
    /// is the winning vote count and `features_evaluated` the total
    /// across voters.
    ///
    /// [`OneVsOneEnsemble::predict`]:
    /// crate::learner::multiclass::OneVsOneEnsemble::predict
    pub fn classify(&self, features: &Features, scratch: &mut ClassifyScratch) -> ScoreResponse {
        self.classify_with(features, scratch, false)
    }

    /// [`Self::classify`] with an optional per-voter cost breakdown:
    /// when `verbose` the response additionally carries one
    /// [`VoterVote`] row per 1-vs-1 voter (pair-enumeration order), so
    /// clients can attribute the attentive feature spend voter by
    /// voter. The vote itself is bit-identical either way — verbose
    /// only records what the non-verbose path already computes.
    pub fn classify_with(
        &self,
        features: &Features,
        scratch: &mut ClassifyScratch,
        verbose: bool,
    ) -> ScoreResponse {
        let ClassifyScratch { orders, tables, pair_slots, tally } = scratch;
        debug_assert_eq!(orders.len(), self.voters.len(), "scratch built for this snapshot");
        tally.clear();
        tally.resize(self.classes.len(), 0);
        let mut evaluated = 0usize;
        let mut per_voter = verbose.then(|| Vec::with_capacity(self.voters.len()));
        let walk = self.voters.iter().zip(orders.iter_mut()).zip(tables.iter_mut());
        for (((voter, orders), cache), &(pos_slot, neg_slot)) in walk.zip(pair_slots.iter()) {
            let (score, k) = match features {
                Features::Dense(x) => {
                    let order = orders.next();
                    let table = cache.for_total(order.len());
                    TabledPredictor::new(table).predict(&voter.weights, x, order)
                }
                Features::Sparse { idx, val } => {
                    let order = orders.next_sparse(&voter.weights, idx);
                    let table = cache.for_total(order.len());
                    TabledPredictor::new(table).predict_sparse(&voter.weights, idx, val, order)
                }
            };
            evaluated += k;
            let (winner, slot) =
                if score >= 0.0 { (voter.pos, pos_slot) } else { (voter.neg, neg_slot) };
            if let Some(count) = tally.get_mut(slot as usize) {
                *count += 1;
            }
            if let Some(rows) = per_voter.as_mut() {
                rows.push(VoterVote {
                    pos: voter.pos,
                    neg: voter.neg,
                    vote: winner,
                    features: k as u32,
                });
            }
        }
        // Ascending scan with a strict compare: the first slot holding
        // the max vote count wins, and classes are ascending — the same
        // smaller-label tie-break as the offline ensemble.
        let mut best = 0usize;
        for (i, &votes) in tally.iter().enumerate() {
            if votes > tally[best] {
                best = i;
            }
        }
        let label = self.classes[best];
        let won = tally[best];
        ScoreResponse {
            score: won as f64,
            features_evaluated: evaluated,
            classify: Some(ClassifyInfo {
                label,
                votes: won,
                voters: self.voters.len() as u32,
            }),
            per_voter,
            degraded: false,
        }
    }

    /// Serialize (for `attentive serve --model name=path`). Tagged with
    /// `"kind":"ensemble"`; the presence of `voters` is what
    /// [`ServingModel::from_json`] dispatches on.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("kind", Json::Str("ensemble".into())),
            ("classes", Json::Arr(self.classes.iter().map(|&c| Json::Num(c as f64)).collect())),
            ("boundary", self.boundary.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
            (
                "voters",
                Json::Arr(
                    self.voters
                        .iter()
                        .map(|v| {
                            Json::obj([
                                ("pos", Json::Num(v.pos as f64)),
                                ("neg", Json::Num(v.neg as f64)),
                                (
                                    "weights",
                                    Json::Arr(v.weights.iter().map(|&w| Json::Num(w)).collect()),
                                ),
                                ("var_sn", Json::Num(v.var_sn)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the form produced by [`Self::to_json`], enforcing the
    /// structural invariants serving relies on: ≥ 2 strictly increasing
    /// classes, exactly `C(C-1)/2` voters in pair-enumeration order,
    /// and one shared dimensionality.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let classes: Vec<i64> = v
            .get("classes")
            .and_then(|a| a.as_arr())
            .ok_or("ensemble: missing classes")?
            .iter()
            .map(|x| x.as_i64().ok_or_else(|| "ensemble: non-integer class".to_string()))
            .collect::<Result<_, _>>()?;
        if classes.len() < 2 {
            return Err("ensemble: needs >= 2 classes".into());
        }
        if !classes.windows(2).all(|w| w[0] < w[1]) {
            return Err("ensemble: classes must be strictly increasing".into());
        }
        let boundary =
            AnyBoundary::from_json(v.get("boundary").ok_or("ensemble: missing boundary")?)?;
        let policy = CoordinatePolicy::from_name(
            v.get("policy").and_then(|s| s.as_str()).ok_or("ensemble: missing policy")?,
        )?;
        let voter_docs =
            v.get("voters").and_then(|a| a.as_arr()).ok_or("ensemble: missing voters")?;
        let mut voters = Vec::with_capacity(voter_docs.len());
        for doc in voter_docs {
            voters.push(VoterSnapshot {
                pos: doc.get("pos").and_then(|x| x.as_i64()).ok_or("ensemble voter: missing pos")?,
                neg: doc.get("neg").and_then(|x| x.as_i64()).ok_or("ensemble voter: missing neg")?,
                weights: doc
                    .get("weights")
                    .and_then(|a| a.as_arr())
                    .ok_or("ensemble voter: missing weights")?
                    .iter()
                    .map(|x| {
                        x.as_f64().ok_or_else(|| "ensemble voter: non-numeric weight".to_string())
                    })
                    .collect::<Result<_, _>>()?,
                var_sn: doc
                    .get("var_sn")
                    .and_then(|x| x.as_f64())
                    .ok_or("ensemble voter: missing var_sn")?,
            });
        }
        // The voter list must be exactly the pair enumeration: the vote
        // mapping (and the offline-equivalence guarantee) depends on it.
        let mut expected = Vec::new();
        for a in 0..classes.len() {
            for b in a + 1..classes.len() {
                expected.push((classes[a], classes[b]));
            }
        }
        if voters.len() != expected.len() {
            return Err(format!(
                "ensemble: {} voters for {} classes (need {})",
                voters.len(),
                classes.len(),
                expected.len()
            ));
        }
        for (voter, (pos, neg)) in voters.iter().zip(&expected) {
            if (voter.pos, voter.neg) != (*pos, *neg) {
                return Err(format!(
                    "ensemble: voter pair ({}, {}) out of enumeration order (expected ({pos}, {neg}))",
                    voter.pos, voter.neg
                ));
            }
        }
        let dim = voters[0].weights.len();
        if voters.iter().any(|v| v.weights.len() != dim) {
            return Err("ensemble: voters disagree on dimensionality".into());
        }
        Ok(Self { classes, boundary, policy, voters })
    }
}

/// Reusable per-worker classify state built by
/// [`EnsembleSnapshot::make_scratch`]: order generators and threshold
/// tables (one per voter), the voter→tally-slot map, and the vote tally
/// buffer. Holding this across requests is what makes the classify hot
/// path allocation-free: the old per-call `Vec<(class, votes)>` and its
/// O(C) linear scan per voter are replaced by a cleared-and-reused
/// buffer indexed through the precomputed slots.
#[derive(Debug, Clone)]
pub struct ClassifyScratch {
    /// One coordinate-order generator per voter (pair-enumeration order).
    orders: Vec<OrderGenerator>,
    /// One threshold-table cache per voter (its own `var_sn`).
    tables: Vec<TableCache>,
    /// Tally slots for each voter's (pos, neg) classes; `u32::MAX` marks
    /// a class missing from `classes` (hand-built snapshots only).
    pair_slots: Vec<(u32, u32)>,
    /// Vote tally, one slot per class, cleared per request.
    tally: Vec<u32>,
}

/// What a serving shard hosts: one binary model or an all-pairs
/// multiclass ensemble. The service and hub are generic over this, so
/// both kinds get identical batching, generation-pinning, and
/// drain-on-swap semantics.
#[derive(Debug, Clone)]
pub enum ServingModel {
    /// A single binary model answering `score` requests.
    Binary(ModelSnapshot),
    /// An all-pairs ensemble answering `classify` requests.
    Ensemble(EnsembleSnapshot),
}

impl From<ModelSnapshot> for ServingModel {
    fn from(snapshot: ModelSnapshot) -> Self {
        ServingModel::Binary(snapshot)
    }
}

impl From<EnsembleSnapshot> for ServingModel {
    fn from(snapshot: EnsembleSnapshot) -> Self {
        ServingModel::Ensemble(snapshot)
    }
}

impl ServingModel {
    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            ServingModel::Binary(m) => m.weights.len(),
            ServingModel::Ensemble(e) => e.dim(),
        }
    }

    /// `"binary"` or `"ensemble"` — the wire name of the model kind.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ServingModel::Binary(_) => "binary",
            ServingModel::Ensemble(_) => "ensemble",
        }
    }

    /// Voters behind this model (0 for a binary model).
    pub fn voter_count(&self) -> usize {
        match self {
            ServingModel::Binary(_) => 0,
            ServingModel::Ensemble(e) => e.voter_count(),
        }
    }

    /// The request kind this model answers.
    pub fn kind(&self) -> ReqKind {
        match self {
            ServingModel::Binary(_) => ReqKind::Score,
            ServingModel::Ensemble(_) => ReqKind::Classify,
        }
    }

    /// Serialize: a binary model keeps the legacy untagged
    /// [`ModelSnapshot`] form (existing snapshot files and v1 `reload`
    /// payloads stay valid); an ensemble is the tagged form with
    /// `voters`.
    pub fn to_json(&self) -> Json {
        match self {
            ServingModel::Binary(m) => m.to_json(),
            ServingModel::Ensemble(e) => e.to_json(),
        }
    }

    /// Parse either form, dispatching on the presence of `voters`.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        if v.get("voters").is_some() {
            EnsembleSnapshot::from_json(v).map(ServingModel::Ensemble)
        } else {
            ModelSnapshot::from_json(v).map(ServingModel::Binary)
        }
    }
}

/// A scoring payload: dense vector or sparse `(idx, val)` pairs.
///
/// The sparse form is the wire protocol v2 request shape and flows
/// through the hub and the worker loop **without densifying**: the
/// early-stopped walk visits only the support, so per-request cost
/// scales with the number of nonzeros, not the model dimensionality.
#[derive(Debug, Clone)]
pub enum Features {
    /// Dense feature vector (length must equal the model dim).
    Dense(Vec<f64>),
    /// Sparse pairs. Indices must be strictly increasing (canonical
    /// form; rejected otherwise by [`Features::validate`]) and values
    /// finite. Zero coordinates contribute nothing to a linear margin,
    /// so scoring the support alone is lossless.
    Sparse {
        /// Coordinate indices, strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates, parallel to `idx`.
        val: Vec<f64>,
    },
}

impl From<Vec<f64>> for Features {
    fn from(features: Vec<f64>) -> Self {
        Features::Dense(features)
    }
}

impl Features {
    /// Number of stored coordinates (dense: the full length).
    pub fn nnz(&self) -> usize {
        match self {
            Features::Dense(x) => x.len(),
            Features::Sparse { idx, .. } => idx.len(),
        }
    }

    /// Structural validation, independent of any model: parallel array
    /// lengths, strictly increasing indices (no duplicates), and finite
    /// values. Both wire parsers (JSON and binary) call this before a
    /// request can reach the workers.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            Features::Dense(x) => {
                if !x.iter().all(|v| v.is_finite()) {
                    return Err("non-finite feature value".into());
                }
            }
            Features::Sparse { idx, val } => {
                if idx.len() != val.len() {
                    return Err(format!(
                        "sparse idx/val length mismatch: {} vs {}",
                        idx.len(),
                        val.len()
                    ));
                }
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    return Err("sparse idx must be strictly increasing".into());
                }
                if !val.iter().all(|v| v.is_finite()) {
                    return Err("non-finite feature value".into());
                }
            }
        }
        Ok(())
    }

    /// Check compatibility with a model of dimensionality `dim`.
    /// Returns `Err((expected, got))` on mismatch; for sparse payloads
    /// `got` is `max_idx + 1` (the minimum dim that would fit them).
    /// Scans every index rather than trusting `idx.last()`, so the
    /// screen is sound even for non-canonical (unsorted) payloads a
    /// library caller might feed straight into the hub — nothing that
    /// passes this check can index out of bounds in the worker.
    pub fn check_dim(&self, dim: usize) -> Result<(), (usize, usize)> {
        match self {
            Features::Dense(x) => {
                if x.len() != dim {
                    return Err((dim, x.len()));
                }
            }
            Features::Sparse { idx, .. } => {
                if let Some(&max) = idx.iter().max() {
                    if max as usize >= dim {
                        return Err((dim, max as usize + 1));
                    }
                }
            }
        }
        Ok(())
    }

    /// Materialize a dense vector (tests and diagnostics only — the
    /// serving path never densifies).
    pub fn densify(&self, dim: usize) -> Vec<f64> {
        match self {
            Features::Dense(x) => x.clone(),
            Features::Sparse { idx, val } => {
                let mut out = vec![0.0; dim];
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    if (i as usize) < dim {
                        out[i as usize] = v;
                    }
                }
                out
            }
        }
    }

    /// Sparsify a dense vector: keep entries with `|v| > eps`. The
    /// client-side converse of [`Features::densify`].
    pub fn sparsify(features: &[f64], eps: f64) -> Features {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        Features::sparsify_into(features, eps, &mut idx, &mut val);
        Features::Sparse { idx, val }
    }

    /// [`Features::sparsify`] into caller-supplied buffers (cleared and
    /// refilled) — the allocation-free form for encode loops that
    /// sparsify per request (the load generator's hot path).
    pub fn sparsify_into(features: &[f64], eps: f64, idx: &mut Vec<u32>, val: &mut Vec<f64>) {
        idx.clear();
        val.clear();
        for (i, &v) in features.iter().enumerate() {
            if v.abs() > eps {
                idx.push(i as u32);
                val.push(v);
            }
        }
    }
}

/// Which evaluation a request asks for. Must match the serving model's
/// kind ([`ServingModel::kind`]); the hub screens mismatches before
/// admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqKind {
    /// Binary margin (`score` op) — needs a [`ServingModel::Binary`].
    Score,
    /// All-pairs vote (`classify` op) — needs a
    /// [`ServingModel::Ensemble`].
    Classify,
    /// All-pairs vote with the per-voter cost breakdown (`classify`
    /// with `verbose`, or the binary `CLASSIFY_SPARSE_VERBOSE` op) —
    /// same admission rules as [`ReqKind::Classify`].
    ClassifyVerbose,
}

impl ReqKind {
    /// Wire name of the op.
    pub fn name(self) -> &'static str {
        match self {
            ReqKind::Score => "score",
            ReqKind::Classify | ReqKind::ClassifyVerbose => "classify",
        }
    }

    /// The admission kind: verbose classify is still a classify as far
    /// as model-kind screening is concerned.
    pub fn base(self) -> ReqKind {
        match self {
            ReqKind::ClassifyVerbose => ReqKind::Classify,
            other => other,
        }
    }
}

/// Admission lane for the two-lane priority queue: `Interactive` work
/// (single score/classify requests by default) is dequeued ahead of
/// `Bulk` work (whole `SCORE_BATCH` fan-in by default), with a weighted
/// pick so a saturated interactive lane can never starve bulk outright
/// — and bulk fan-in can never starve singles at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Latency-sensitive lane, preferred at dequeue.
    Interactive,
    /// Throughput lane; guaranteed at least every
    /// [`BULK_EVERY`]-th pick when both lanes are non-empty, and the
    /// first to be rejected under the brownout `shed` tier.
    Bulk,
}

/// Per-request admission options ([`ServiceHandle::submit_opts`] /
/// the hub's `submit_pinned_opts`): an optional absolute deadline —
/// work still queued past it is answered `DEADLINE_EXCEEDED` at
/// dequeue instead of being scored — and an optional lane override.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOpts {
    /// Absolute expiry; `None` (the default) means no deadline.
    pub deadline: Option<Instant>,
    /// Lane override; `None` takes the op default (singles →
    /// interactive, batches → bulk).
    pub lane: Option<Lane>,
}

/// One scoring request (internal envelope).
struct ScoreRequest {
    features: Features,
    kind: ReqKind,
    /// Absolute deadline; checked at dequeue, not during scoring.
    deadline: Option<Instant>,
    respond: SyncSender<ScoreResponse>,
}

/// A whole wire batch admitted as **one** queue unit: it occupies a
/// single queue slot and costs a single worker wakeup, and its examples
/// are scored back-to-back by one worker in submission order — driving
/// the order-generator stream exactly as k single submissions would, so
/// batched results are bit-identical to singles.
struct BatchRequest {
    examples: Vec<Features>,
    /// Absolute deadline for the whole batch (every slot answers
    /// `DEADLINE_EXCEEDED` when it expires in the queue).
    deadline: Option<Instant>,
    respond: SyncSender<Vec<ScoreResponse>>,
}

/// What travels on the service queue. Every unit is stamped at
/// admission so workers can attribute queue-wait time (the brownout
/// controller's latency signal) and check deadlines at dequeue.
struct Work {
    payload: Payload,
    /// When this unit entered the admission queue.
    enqueued: Instant,
}

enum Payload {
    One(ScoreRequest),
    Batch(BatchRequest),
}

/// Multiclass outcome attached to a classify response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassifyInfo {
    /// Predicted class (the vote winner; ties break toward the smaller
    /// label).
    pub label: i64,
    /// Votes the winner collected.
    pub votes: u32,
    /// Voters consulted (`C(C-1)/2`).
    pub voters: u32,
}

/// One voter's row of a verbose-classify breakdown: which 1-vs-1 pair,
/// which way it voted, and what the attentive early exit spent on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VoterVote {
    /// Class a positive margin votes for.
    pub pos: i64,
    /// Class a negative margin votes for.
    pub neg: i64,
    /// The class this voter actually voted for (`pos` or `neg`).
    pub vote: i64,
    /// Features this voter evaluated before its early exit.
    pub features: u32,
}

/// Scoring result.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    /// Binary requests: signed margin estimate (the prediction is its
    /// sign). Classify requests: the winning vote count.
    pub score: f64,
    /// Features evaluated before the early exit (for classify: summed
    /// across all voters).
    pub features_evaluated: usize,
    /// The multiclass outcome (classify requests only).
    pub classify: Option<ClassifyInfo>,
    /// Per-voter cost breakdown (verbose classify requests only), in
    /// pair-enumeration order.
    pub per_voter: Option<Vec<VoterVote>>,
    /// Scored under a brownout tier (tightened stopping boundary): the
    /// answer traded a sliver of decision confidence for queue relief.
    /// Always `false` when brownout is disabled.
    pub degraded: bool,
}

/// `features_evaluated` value of the [`ScoreResponse::deadline_exceeded`]
/// sentinel (one below the internal-fault sentinel's `usize::MAX`).
const DEADLINE_SENTINEL: usize = usize::MAX - 1;

impl ScoreResponse {
    /// The internal-fault sentinel: a worker panicked while evaluating
    /// this example (contained by `catch_unwind`). Distinguished from
    /// the plain NaN reject sentinel by the impossible
    /// `features_evaluated` value, so the front-end can render it as
    /// the retryable `internal` error instead of `dimension-mismatch`.
    pub fn internal_fault() -> Self {
        ScoreResponse {
            score: f64::NAN,
            features_evaluated: usize::MAX,
            classify: None,
            per_voter: None,
            degraded: false,
        }
    }

    /// Is this the [`Self::internal_fault`] sentinel?
    pub fn is_internal_fault(&self) -> bool {
        self.score.is_nan() && self.features_evaluated == usize::MAX
    }

    /// The deadline-shed sentinel: the request's deadline expired while
    /// it sat in the admission queue, so the worker answered without
    /// scoring it. Distinguished from the other NaN sentinels by its own
    /// impossible `features_evaluated` value; the front-end renders it
    /// as the retryable `deadline-exceeded` error.
    pub fn deadline_exceeded() -> Self {
        ScoreResponse {
            score: f64::NAN,
            features_evaluated: DEADLINE_SENTINEL,
            classify: None,
            per_voter: None,
            degraded: false,
        }
    }

    /// Is this the [`Self::deadline_exceeded`] sentinel?
    pub fn is_deadline_exceeded(&self) -> bool {
        self.score.is_nan() && self.features_evaluated == DEADLINE_SENTINEL
    }
}

/// Number of log2-spaced buckets in the features-touched histogram:
/// bucket 0 counts requests that touched 0 features, bucket `i ≥ 1` counts
/// requests that touched `[2^(i-1), 2^i)` features; the last bucket
/// absorbs everything above.
pub const FEATURE_BUCKETS: usize = 16;

/// Histogram bucket index for `evaluated` features.
#[inline]
fn feature_bucket(evaluated: usize) -> usize {
    if evaluated == 0 {
        0
    } else {
        ((usize::BITS - evaluated.leading_zeros()) as usize).min(FEATURE_BUCKETS - 1)
    }
}

/// Live service counters (lock-free reads).
#[derive(Debug)]
pub struct ServiceStats {
    served: AtomicU64,
    features: AtomicU64,
    batches: AtomicU64,
    early_exits: AtomicU64,
    panics: AtomicU64,
    /// Requests answered `DEADLINE_EXCEEDED` at dequeue (not scored,
    /// not in `served`).
    deadline_sheds: AtomicU64,
    /// Responses scored under a brownout tier (tightened boundary).
    degraded: AtomicU64,
    /// Current brownout tier gauge (0 = normal .. 3 = shed), written by
    /// the controller and read by the workers each drain.
    tier: AtomicU64,
    /// Brownout tier transitions (either direction).
    tier_transitions: AtomicU64,
    /// Total queue wait attributed at dequeue, in microseconds, and its
    /// sample count — the controller turns deltas of these into the
    /// latency EWMA.
    wait_us: AtomicU64,
    wait_samples: AtomicU64,
    hist: [AtomicU64; FEATURE_BUCKETS],
}

impl Default for ServiceStats {
    fn default() -> Self {
        Self {
            served: AtomicU64::new(0),
            features: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            early_exits: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            deadline_sheds: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            tier: AtomicU64::new(0),
            tier_transitions: AtomicU64::new(0),
            wait_us: AtomicU64::new(0),
            wait_samples: AtomicU64::new(0),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// A snapshot of [`ServiceStats`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsSnapshot {
    /// Requests served.
    pub served: u64,
    /// Total features evaluated.
    pub features: u64,
    /// Batches drained.
    pub batches: u64,
    /// Requests that exited before touching every coordinate.
    pub early_exits: u64,
    /// Worker evaluations that panicked and were contained
    /// (`catch_unwind`): each answered the retryable `internal` error
    /// and does not count in `served`.
    pub panics: u64,
    /// Requests answered `DEADLINE_EXCEEDED` at dequeue instead of
    /// being scored (not in `served`).
    pub deadline_sheds: u64,
    /// Responses scored under a brownout tier (tightened boundary).
    pub degraded: u64,
    /// Current brownout tier (0 = normal .. 3 = shed). A gauge, not a
    /// counter: [`Self::add`] takes the max across generations.
    pub tier: u64,
    /// Brownout tier transitions (either direction).
    pub tier_transitions: u64,
    /// Features-touched histogram (see [`FEATURE_BUCKETS`]).
    pub hist: [u64; FEATURE_BUCKETS],
}

impl StatsSnapshot {
    /// Mean features per request.
    pub fn avg_features(&self) -> f64 {
        if self.served == 0 { 0.0 } else { self.features as f64 / self.served as f64 }
    }

    /// Fraction of requests that exited early.
    pub fn early_exit_rate(&self) -> f64 {
        if self.served == 0 { 0.0 } else { self.early_exits as f64 / self.served as f64 }
    }

    /// Approximate `p`-th percentile (`p ∈ [0, 1]`) of features touched
    /// per request, reported as the inclusive upper edge of the histogram
    /// bucket the percentile falls in (0 when nothing was served).
    pub fn feature_percentile(&self, p: f64) -> u64 {
        let total: u64 = self.hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.hist.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        (1u64 << (FEATURE_BUCKETS - 1)) - 1
    }

    /// Accumulate another snapshot (e.g. a retired service generation
    /// after a hot model reload).
    pub fn add(&mut self, other: &StatsSnapshot) {
        self.served += other.served;
        self.features += other.features;
        self.batches += other.batches;
        self.early_exits += other.early_exits;
        self.panics += other.panics;
        self.deadline_sheds += other.deadline_sheds;
        self.degraded += other.degraded;
        // Tier is a gauge: retired generations idle at 0, so the max is
        // the live generation's tier.
        self.tier = self.tier.max(other.tier);
        self.tier_transitions += other.tier_transitions;
        for (a, b) in self.hist.iter_mut().zip(other.hist.iter()) {
            *a += *b;
        }
    }
}

impl ServiceStats {
    /// Record one served request.
    #[inline]
    fn record(&self, evaluated: usize, dim: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.features.fetch_add(evaluated as u64, Ordering::Relaxed);
        if evaluated < dim {
            self.early_exits.fetch_add(1, Ordering::Relaxed);
        }
        self.hist[feature_bucket(evaluated)].fetch_add(1, Ordering::Relaxed);
    }

    /// Read the counters.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            served: self.served.load(Ordering::Relaxed),
            features: self.features.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            early_exits: self.early_exits.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            tier: self.tier.load(Ordering::Relaxed),
            tier_transitions: self.tier_transitions.load(Ordering::Relaxed),
            hist: std::array::from_fn(|i| self.hist[i].load(Ordering::Relaxed)),
        }
    }
}

/// Why a non-blocking submission was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is full — shed load now, retry later.
    Overloaded,
    /// The service has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "service overloaded"),
            SubmitError::Closed => write!(f, "service closed"),
        }
    }
}

/// Fired by a worker after it sends each response. Cloneable and cheap;
/// the default is a no-op, so the threaded I/O backend (which blocks on
/// the response channel directly) pays nothing. The event-loop backend
/// installs a callback that signals its pollers' wake fds, turning
/// "a completion landed" into an epoll event instead of a tick poll.
#[derive(Clone, Default)]
pub struct CompletionNotifier {
    f: Option<Arc<dyn Fn() + Send + Sync>>,
}

impl CompletionNotifier {
    /// A notifier that calls `f` on every completion.
    pub fn new(f: impl Fn() + Send + Sync + 'static) -> Self {
        Self { f: Some(Arc::new(f)) }
    }

    /// Fire the notifier (no-op unless a callback is installed).
    #[inline]
    pub fn notify(&self) {
        if let Some(f) = &self.f {
            f();
        }
    }

    /// Whether a callback is installed.
    pub fn is_active(&self) -> bool {
        self.f.is_some()
    }
}

impl std::fmt::Debug for CompletionNotifier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CompletionNotifier({})", if self.f.is_some() { "active" } else { "no-op" })
    }
}

/// When both lanes are non-empty, every `BULK_EVERY`-th dequeue serves
/// the bulk lane: interactive work is strongly preferred, but bulk can
/// never be starved outright.
const BULK_EVERY: u32 = 4;

/// Outcome of a non-blocking [`LaneQueue`] push.
enum PushError {
    /// Queue at capacity (or bulk shed under brownout tier 3); the
    /// work is handed back for the blocking path.
    Full(Work),
    /// Every handle dropped: the service is shutting down.
    Closed,
}

/// Bounded two-lane admission queue with weighted dequeue — the
/// priority-admission leg of the overload-brownout subsystem. Replaces
/// the old single `sync_channel`: one shared capacity bound (so the
/// backpressure story is unchanged), but interactive work overtakes
/// queued bulk batches instead of waiting behind them.
struct LaneQueue {
    state: Mutex<LaneState>,
    /// Signaled on push and close (workers wait here).
    work: Condvar,
    /// Signaled on drain and close (blocked senders wait here).
    space: Condvar,
    capacity: usize,
    /// Brownout `shed` tier: reject bulk admissions outright (set by
    /// the controller, checked lock-free on the push paths).
    shed_bulk: AtomicBool,
}

struct LaneState {
    interactive: VecDeque<Work>,
    bulk: VecDeque<Work>,
    /// Consecutive interactive picks while bulk waited.
    streak: u32,
    /// Live [`ServiceHandle`] count; 0 closes the queue.
    senders: usize,
    closed: bool,
}

/// Poison-tolerant lock: a panicking worker must never wedge the queue
/// for its respawned replacement or for submitters.
fn lane_lock(queue: &LaneQueue) -> MutexGuard<'_, LaneState> {
    match queue.state.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl LaneQueue {
    fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(LaneState {
                interactive: VecDeque::new(),
                bulk: VecDeque::new(),
                streak: 0,
                senders: 1,
                closed: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            capacity,
            shed_bulk: AtomicBool::new(false),
        }
    }

    /// Weighted pick under the lock: interactive preferred; every
    /// [`BULK_EVERY`]-th pick takes bulk when both lanes are non-empty.
    fn pick(state: &mut LaneState) -> Option<Work> {
        let take_bulk = if state.interactive.is_empty() {
            true
        } else if state.bulk.is_empty() {
            false
        } else {
            state.streak >= BULK_EVERY - 1
        };
        if take_bulk {
            if let Some(work) = state.bulk.pop_front() {
                state.streak = 0;
                return Some(work);
            }
        }
        let work = state.interactive.pop_front();
        if work.is_some() {
            state.streak = state.streak.saturating_add(1);
        }
        work
    }

    /// Non-blocking push. Bulk pushes are rejected outright while the
    /// brownout controller holds the shard in its `shed` tier.
    fn try_push(&self, work: Work, lane: Lane) -> Result<(), PushError> {
        if lane == Lane::Bulk && self.shed_bulk.load(Ordering::Relaxed) {
            return Err(PushError::Full(work));
        }
        let mut st = lane_lock(self);
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.interactive.len() + st.bulk.len() >= self.capacity {
            return Err(PushError::Full(work));
        }
        match lane {
            Lane::Interactive => st.interactive.push_back(work),
            Lane::Bulk => st.bulk.push_back(work),
        }
        drop(st);
        self.work.notify_one();
        Ok(())
    }

    /// Blocking push: waits for queue room (backpressure), failing only
    /// on shutdown — or immediately for bulk work under the `shed` tier
    /// (brownout sheds bulk, it does not buffer it).
    fn push_blocking(&self, work: Work, lane: Lane) -> Result<(), ()> {
        if lane == Lane::Bulk && self.shed_bulk.load(Ordering::Relaxed) {
            return Err(());
        }
        let mut st = lane_lock(self);
        loop {
            if st.closed {
                return Err(());
            }
            if st.interactive.len() + st.bulk.len() < self.capacity {
                match lane {
                    Lane::Interactive => st.interactive.push_back(work),
                    Lane::Bulk => st.bulk.push_back(work),
                }
                drop(st);
                self.work.notify_one();
                return Ok(());
            }
            st = match self.space.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Blocking weighted drain: waits for the first unit, then
    /// opportunistically fills `batch` up to `max_batch` — dynamic
    /// batching without a timer, exactly as the old channel drain.
    /// Returns `false` when the queue is closed and fully drained.
    fn drain(&self, batch: &mut Vec<Work>, max_batch: usize) -> bool {
        let mut st = lane_lock(self);
        loop {
            if let Some(first) = Self::pick(&mut st) {
                batch.push(first);
                break;
            }
            if st.closed {
                return false;
            }
            st = match self.work.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        while batch.len() < max_batch {
            match Self::pick(&mut st) {
                Some(work) => batch.push(work),
                None => break,
            }
        }
        drop(st); // lock released before compute
        self.space.notify_all();
        true
    }

    /// Whether every handle has dropped (the brownout controller's exit
    /// signal).
    fn is_closed(&self) -> bool {
        lane_lock(self).closed
    }

    /// Flip bulk shedding (brownout tier 3).
    fn set_shed_bulk(&self, shed: bool) {
        self.shed_bulk.store(shed, Ordering::Relaxed);
    }
}

/// Handle for submitting requests to a running service. Cloneable;
/// dropping every handle shuts the workers down.
pub struct ServiceHandle {
    queue: Arc<LaneQueue>,
    /// Work units currently waiting in the admission queue. Incremented
    /// *before* a send attempt (and rolled back on rejection) so the
    /// counter is always ≥ the true occupancy — never underflowing when
    /// a worker drains the unit before the submitter's bump lands.
    depth: Arc<AtomicUsize>,
    /// The queue's capacity bound.
    capacity: usize,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        lane_lock(&self.queue).senders += 1;
        Self { queue: Arc::clone(&self.queue), depth: Arc::clone(&self.depth), capacity: self.capacity }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        let mut st = lane_lock(&self.queue);
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            // Wake draining workers and blocked senders so they observe
            // the close.
            self.queue.work.notify_all();
            self.queue.space.notify_all();
        }
    }
}

impl ServiceHandle {
    /// Score one feature payload (dense `Vec<f64>` or sparse
    /// [`Features`]), blocking until the result arrives. Returns `None`
    /// if the service has shut down or the queue is persistently full
    /// (backpressure).
    pub fn score(&self, features: impl Into<Features>) -> Option<ScoreResponse> {
        self.call(features, ReqKind::Score)
    }

    /// Classify one payload against an ensemble service, blocking until
    /// the result arrives (see [`Self::score`] for the `None` cases).
    pub fn classify(&self, features: impl Into<Features>) -> Option<ScoreResponse> {
        self.call(features, ReqKind::Classify)
    }

    fn call(&self, features: impl Into<Features>, kind: ReqKind) -> Option<ScoreResponse> {
        let (tx, rx) = sync_channel(1);
        let work = Work {
            payload: Payload::One(ScoreRequest {
                features: features.into(),
                kind,
                deadline: None,
                respond: tx,
            }),
            enqueued: Instant::now(),
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.queue.try_push(work, Lane::Interactive) {
            Ok(()) => {}
            Err(PushError::Full(req)) => {
                // Block on a full queue (backpressure) rather than dropping.
                if self.queue.push_blocking(req, Lane::Interactive).is_err() {
                    self.depth.fetch_sub(1, Ordering::Relaxed);
                    return None;
                }
            }
            Err(PushError::Closed) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                return None;
            }
        }
        rx.recv().ok()
    }

    /// Non-blocking admission: enqueue the request if the bounded queue
    /// has room and return the response receiver, otherwise reject
    /// immediately. This is the load-shedding entry point the network
    /// server builds its explicit `overloaded` responses on — an admitted
    /// request is always answered (workers drain the queue even during a
    /// handle swap), so the receiver's `recv()` will not hang.
    pub fn submit(
        &self,
        features: impl Into<Features>,
    ) -> Result<Receiver<ScoreResponse>, SubmitError> {
        self.submit_kind(features, ReqKind::Score)
    }

    /// [`Self::submit`] with an explicit request kind (`classify` for
    /// ensemble services).
    pub fn submit_kind(
        &self,
        features: impl Into<Features>,
        kind: ReqKind,
    ) -> Result<Receiver<ScoreResponse>, SubmitError> {
        self.submit_opts(features, kind, SubmitOpts::default())
    }

    /// [`Self::submit_kind`] with per-request admission options: an
    /// absolute deadline (checked at dequeue) and/or a lane override
    /// (singles default to the interactive lane).
    pub fn submit_opts(
        &self,
        features: impl Into<Features>,
        kind: ReqKind,
        opts: SubmitOpts,
    ) -> Result<Receiver<ScoreResponse>, SubmitError> {
        let (tx, rx) = sync_channel(1);
        let work = Work {
            payload: Payload::One(ScoreRequest {
                features: features.into(),
                kind,
                deadline: opts.deadline,
                respond: tx,
            }),
            enqueued: Instant::now(),
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.queue.try_push(work, opts.lane.unwrap_or(Lane::Interactive)) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(match e {
                    PushError::Full(_) => SubmitError::Overloaded,
                    PushError::Closed => SubmitError::Closed,
                })
            }
        }
    }

    /// Non-blocking admission of a whole score batch as **one queue
    /// unit** (see [`BatchRequest`]): either every example is admitted
    /// together or the batch is shed as a unit. The receiver yields one
    /// response per example, in submission order; per-example problems
    /// (dimension mismatch) surface as the NaN reject sentinel in that
    /// example's slot and never poison the rest of the batch.
    pub fn submit_batch(
        &self,
        examples: Vec<Features>,
    ) -> Result<Receiver<Vec<ScoreResponse>>, SubmitError> {
        self.submit_batch_opts(examples, SubmitOpts::default())
    }

    /// [`Self::submit_batch`] with per-request admission options
    /// (batches default to the bulk lane).
    pub fn submit_batch_opts(
        &self,
        examples: Vec<Features>,
        opts: SubmitOpts,
    ) -> Result<Receiver<Vec<ScoreResponse>>, SubmitError> {
        let (tx, rx) = sync_channel(1);
        let work = Work {
            payload: Payload::Batch(BatchRequest {
                examples,
                deadline: opts.deadline,
                respond: tx,
            }),
            enqueued: Instant::now(),
        };
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.queue.try_push(work, opts.lane.unwrap_or(Lane::Bulk)) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(match e {
                    PushError::Full(_) => SubmitError::Overloaded,
                    PushError::Closed => SubmitError::Closed,
                })
            }
        }
    }

    /// Current admission-queue occupancy and its capacity bound, read
    /// lock-free. The occupancy is a momentary over-approximation (see
    /// the `depth` field) clamped to capacity; the front-end derives
    /// the adaptive `SCORE_BATCH` admission cap from it.
    pub fn queue_load(&self) -> (usize, usize) {
        (self.depth.load(Ordering::Relaxed).min(self.capacity), self.capacity)
    }
}

/// The prediction service: owns the model and the batching workers.
pub struct PredictionService {
    model: Arc<ServingModel>,
    /// Max requests drained per batch.
    pub max_batch: usize,
    /// Queue capacity (backpressure bound).
    pub queue: usize,
    /// Worker threads.
    pub workers: usize,
    seed: u64,
    notifier: CompletionNotifier,
    /// Overload-brownout controller config; `None` (the default) spawns
    /// no controller and keeps scoring bit-identical to the undegraded
    /// path.
    brownout: Option<BrownoutConfig>,
}

/// A running service: join handles + stats.
pub struct RunningService {
    /// Shared counters.
    pub stats: Arc<ServiceStats>,
    handles: Vec<JoinHandle<()>>,
}

impl RunningService {
    /// Wait for workers to finish (after all [`ServiceHandle`]s drop).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

impl PredictionService {
    /// Service over a serving model (a binary [`ModelSnapshot`] converts
    /// implicitly; pass a [`ServingModel::Ensemble`] for classify
    /// serving).
    pub fn new(
        model: impl Into<ServingModel>,
        max_batch: usize,
        queue: usize,
        seed: u64,
    ) -> Self {
        Self {
            model: Arc::new(model.into()),
            max_batch: max_batch.max(1),
            queue: queue.max(1),
            workers: 1,
            seed,
            notifier: CompletionNotifier::default(),
            brownout: None,
        }
    }

    /// Use `n` worker threads.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Fire `notifier` after every response send (see
    /// [`CompletionNotifier`]).
    pub fn with_notifier(mut self, notifier: CompletionNotifier) -> Self {
        self.notifier = notifier;
        self
    }

    /// Run the overload-brownout controller over this service (see
    /// [`BrownoutConfig`]); `None` disables it.
    pub fn with_brownout(mut self, brownout: Option<BrownoutConfig>) -> Self {
        self.brownout = brownout;
        self
    }

    /// Start the workers. Returns a request handle and the running
    /// service (stats + joins).
    pub fn spawn(self) -> (ServiceHandle, RunningService) {
        let queue = Arc::new(LaneQueue::new(self.queue));
        let stats = Arc::new(ServiceStats::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let tighten = self.brownout.as_ref().map(|b| b.tighten);
        let mut handles = Vec::new();
        for worker_id in 0..self.workers {
            let queue = queue.clone();
            let model = self.model.clone();
            let stats = stats.clone();
            let depth = depth.clone();
            let max_batch = self.max_batch;
            let seed = self.seed ^ (worker_id as u64) << 32;
            let notifier = self.notifier.clone();
            // Respawn on escaped panics: per-example evaluation is
            // already contained inside the loop, so this outer loop is
            // the backstop that keeps a shard from wedging if a panic
            // slips out anywhere else in the worker body. A normal
            // queue-closed exit breaks out.
            handles.push(std::thread::spawn(move || loop {
                let body = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    worker_loop(
                        queue.clone(),
                        model.clone(),
                        stats.clone(),
                        depth.clone(),
                        max_batch,
                        seed,
                        notifier.clone(),
                        tighten,
                    )
                }));
                match body {
                    Ok(()) => break,
                    Err(_) => {
                        stats.panics.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        if let Some(cfg) = self.brownout {
            let queue = queue.clone();
            let stats = stats.clone();
            let depth = depth.clone();
            let capacity = self.queue;
            handles.push(std::thread::spawn(move || {
                brownout_controller(&queue, &stats, &depth, capacity, &cfg)
            }));
        }
        (
            ServiceHandle { queue, depth, capacity: self.queue },
            RunningService { stats, handles },
        )
    }
}

/// Highest brownout tier (`shed`): tier 2's tightened boundary plus
/// outright rejection of bulk-lane admissions.
const MAX_TIER: u64 = 3;

/// The brownout feedback loop, one thread per spawned service
/// generation: every `sample_ms` it reads queue occupancy (and, when a
/// latency target is configured, a queue-wait EWMA from the workers'
/// dequeue-time accounting) into a pressure signal in [0, 1], then
/// walks the tier gauge one step at a time with hysteresis — pressure
/// must sit above `enter` (or below `exit`) for a full `dwell_ms`
/// before a transition fires, and each further step needs its own
/// dwell. Exits when every [`ServiceHandle`] has dropped.
fn brownout_controller(
    queue: &LaneQueue,
    stats: &ServiceStats,
    depth: &AtomicUsize,
    capacity: usize,
    cfg: &BrownoutConfig,
) {
    let mut tier: u64 = 0;
    let mut ewma_us: f64 = 0.0;
    let mut last_wait_us: u64 = 0;
    let mut last_samples: u64 = 0;
    // A pending transition: direction (+1 / -1) and when its condition
    // first held.
    let mut pending: Option<(i64, Instant)> = None;
    while !queue.is_closed() {
        std::thread::sleep(Duration::from_millis(cfg.sample_ms.max(1)));
        let occupancy = depth.load(Ordering::Relaxed).min(capacity) as f64 / capacity as f64;
        let mut pressure = occupancy;
        if cfg.latency_target_us > 0 {
            let wait_us = stats.wait_us.load(Ordering::Relaxed);
            let samples = stats.wait_samples.load(Ordering::Relaxed);
            let delta_n = samples.saturating_sub(last_samples);
            if delta_n > 0 {
                let sample = wait_us.saturating_sub(last_wait_us) as f64 / delta_n as f64;
                ewma_us = if last_samples == 0 { sample } else { 0.2 * sample + 0.8 * ewma_us };
            }
            last_wait_us = wait_us;
            last_samples = samples;
            pressure = pressure.max((ewma_us / cfg.latency_target_us as f64).min(1.0));
        }
        let direction: i64 = if pressure > cfg.enter && tier < MAX_TIER {
            1
        } else if pressure < cfg.exit && tier > 0 {
            -1
        } else {
            0
        };
        if direction == 0 {
            pending = None;
            continue;
        }
        let now = Instant::now();
        match pending {
            Some((dir, since)) if dir == direction => {
                if now.duration_since(since) >= Duration::from_millis(cfg.dwell_ms) {
                    tier = (tier as i64 + direction) as u64;
                    stats.tier.store(tier, Ordering::Relaxed);
                    stats.tier_transitions.fetch_add(1, Ordering::Relaxed);
                    queue.set_shed_bulk(tier >= MAX_TIER);
                    // The next step (either direction) needs its own
                    // dwell.
                    pending = None;
                }
            }
            _ => pending = Some((direction, now)),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    queue: Arc<LaneQueue>,
    model: Arc<ServingModel>,
    stats: Arc<ServiceStats>,
    depth: Arc<AtomicUsize>,
    max_batch: usize,
    seed: u64,
    notifier: CompletionNotifier,
    tighten: Option<f64>,
) {
    match &*model {
        ServingModel::Binary(snapshot) => {
            binary_worker(&queue, snapshot, &stats, &depth, max_batch, seed, &notifier, tighten)
        }
        ServingModel::Ensemble(ensemble) => {
            ensemble_worker(&queue, ensemble, &stats, &depth, max_batch, seed, &notifier)
        }
    }
}

/// Dequeue-time bookkeeping shared by both workers: attribute the
/// unit's queue wait (the brownout controller's latency signal) and
/// decide whether its deadline already expired — doomed work is
/// answered `DEADLINE_EXCEEDED` without scoring, which is the whole
/// point of carrying deadlines to the worker. One clock read per unit.
fn dequeue_check(stats: &ServiceStats, enqueued: Instant, deadline: Option<Instant>) -> bool {
    let now = Instant::now();
    let waited = now.duration_since(enqueued).as_micros() as u64;
    stats.wait_us.fetch_add(waited, Ordering::Relaxed);
    stats.wait_samples.fetch_add(1, Ordering::Relaxed);
    matches!(deadline, Some(dl) if now >= dl)
}

/// The reject sentinel for a request the hub's screens should have
/// stopped (wrong kind for the model, or a dimensionality that slipped
/// past admission across a reload): the NaN score renders as a
/// structured error at the front-end.
fn reject() -> ScoreResponse {
    ScoreResponse {
        score: f64::NAN,
        features_evaluated: 0,
        classify: None,
        per_voter: None,
        degraded: false,
    }
}

/// Score one example against a binary snapshot — the single hot path
/// shared by lone requests and batch members, so a batched example
/// drives the order generator and threshold table exactly as the same
/// example submitted alone would (bit-identical scores, feature counts,
/// and early-exit accounting). Returns the response plus the "full
/// evaluation" total for the stats histogram: for sparse payloads that
/// is the support size — zero coordinates are skipped losslessly, so
/// both the walk and the early-exit accounting run against nnz.
fn score_one(
    model: &ModelSnapshot,
    orders: &mut OrderGenerator,
    table: &mut TableCache,
    features: &Features,
) -> (ScoreResponse, usize) {
    let (score, k, total) = match features {
        Features::Dense(x) => {
            let order = orders.next();
            let (s, k) = TabledPredictor::new(table.for_total(order.len()))
                .predict(&model.weights, x, order);
            (s, k, model.weights.len())
        }
        Features::Sparse { idx, val } => {
            let order = orders.next_sparse(&model.weights, idx);
            let (s, k) = TabledPredictor::new(table.for_total(order.len()))
                .predict_sparse(&model.weights, idx, val, order);
            (s, k, idx.len())
        }
    };
    (
        ScoreResponse {
            score,
            features_evaluated: k,
            classify: None,
            per_voter: None,
            degraded: false,
        },
        total,
    )
}

/// [`score_one`] behind `catch_unwind`: a panic mid-evaluation (a
/// poisoned example, or the `worker-panic` fault point) answers the
/// internal-fault sentinel instead of unwinding through the worker, and
/// the evaluation scratch — possibly torn mid-walk — is rebuilt before
/// the next example. Panicked evaluations count in `stats.panics`, not
/// `served`.
fn score_one_contained(
    model: &ModelSnapshot,
    orders: &mut OrderGenerator,
    table: &mut TableCache,
    tighten: f64,
    features: &Features,
    stats: &ServiceStats,
    seed: u64,
) -> (ScoreResponse, usize) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        crate::server::faultpoint::maybe_panic();
        score_one(model, &mut *orders, &mut *table, features)
    }));
    match outcome {
        Ok(pair) => pair,
        Err(_) => {
            stats.panics.fetch_add(1, Ordering::Relaxed);
            let dim = model.weights.len();
            *orders = OrderGenerator::new(model.policy, seed);
            orders.refresh(&model.weights);
            // Rebuild at the same brownout tier the torn cache served.
            *table = TableCache::new_scaled(model.boundary.clone(), model.var_sn, dim, tighten);
            (ScoreResponse::internal_fault(), dim)
        }
    }
}

/// The per-tier threshold tables a binary worker scores against. Tier 0
/// is always the plain construction path (bit-identical to a server
/// with brownout disabled); brown tiers hold the same boundary with τ
/// pre-scaled by `tighten` and `tighten²`, so switching tiers is an
/// index load — no math on the hot path.
struct TierTables {
    tables: Vec<(f64, TableCache)>,
}

impl TierTables {
    fn new(model: &ModelSnapshot, dim: usize, tighten: Option<f64>) -> Self {
        let mut tables = vec![(1.0, TableCache::new(model.boundary.clone(), model.var_sn, dim))];
        if let Some(t) = tighten {
            for factor in [t, t * t] {
                tables.push((
                    factor,
                    TableCache::new_scaled(model.boundary.clone(), model.var_sn, dim, factor),
                ));
            }
        }
        Self { tables }
    }

    /// `(tighten, cache)` for the current tier. Tier 3 (shed) scores
    /// surviving interactive traffic at the brown-2 tables.
    fn select(&mut self, stats: &ServiceStats) -> (f64, &mut TableCache, bool) {
        let tier = stats.tier.load(Ordering::Relaxed) as usize;
        let idx = tier.min(self.tables.len() - 1);
        let entry = &mut self.tables[idx];
        (entry.0, &mut entry.1, idx > 0)
    }
}

#[allow(clippy::too_many_arguments)]
fn binary_worker(
    queue: &LaneQueue,
    model: &ModelSnapshot,
    stats: &ServiceStats,
    depth: &AtomicUsize,
    max_batch: usize,
    seed: u64,
    notifier: &CompletionNotifier,
    tighten: Option<f64>,
) {
    let mut orders = OrderGenerator::new(model.policy, seed);
    orders.refresh(&model.weights);
    let dim = model.weights.len();
    // Stop thresholds depend only on (boundary, var_sn, walk length) —
    // constant per snapshot — so the sqrt-laden closed forms are
    // evaluated once here, not per feature (see stst::BoundaryTable).
    // With brownout enabled that cost is paid once per tier up front.
    let mut tiers = TierTables::new(model, dim, tighten);
    let mut batch: Vec<Work> = Vec::with_capacity(max_batch);
    while queue.drain(&mut batch, max_batch) {
        depth.fetch_sub(batch.len(), Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        for work in batch.drain(..) {
            // Tier is re-read per work unit, not per batch: a controller
            // transition mid-drain takes effect on the next example.
            let (factor, table, browned) = tiers.select(stats);
            match work.payload {
                Payload::One(req) => {
                    if dequeue_check(stats, work.enqueued, req.deadline) {
                        stats.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(ScoreResponse::deadline_exceeded());
                        notifier.notify();
                        continue;
                    }
                    // Dimension-mismatch rejects land in bucket 0 and
                    // count as "early exit"; the network front-end
                    // screens those out before admission, so served
                    // traffic keeps the histogram honest.
                    let (mut resp, total) =
                        if req.kind != ReqKind::Score || req.features.check_dim(dim).is_err() {
                            (reject(), dim)
                        } else {
                            score_one_contained(
                                model,
                                &mut orders,
                                table,
                                factor,
                                &req.features,
                                stats,
                                seed,
                            )
                        };
                    if !resp.is_internal_fault() {
                        stats.record(resp.features_evaluated, total);
                        if browned {
                            resp.degraded = true;
                            stats.degraded.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let _ = req.respond.send(resp);
                    notifier.notify();
                }
                Payload::Batch(b) => {
                    if dequeue_check(stats, work.enqueued, b.deadline) {
                        // The whole batch is doomed together — one
                        // deadline covers it, shed counts per example.
                        stats
                            .deadline_sheds
                            .fetch_add(b.examples.len() as u64, Ordering::Relaxed);
                        let out =
                            vec![ScoreResponse::deadline_exceeded(); b.examples.len()];
                        let _ = b.respond.send(out);
                        notifier.notify();
                        continue;
                    }
                    // One wakeup, k examples: scored back-to-back in
                    // submission order. A bad example rejects alone;
                    // the rest of the batch is unaffected.
                    let mut out = Vec::with_capacity(b.examples.len());
                    for features in &b.examples {
                        let (mut resp, total) = if features.check_dim(dim).is_err() {
                            (reject(), dim)
                        } else {
                            score_one_contained(
                                model,
                                &mut orders,
                                table,
                                factor,
                                features,
                                stats,
                                seed,
                            )
                        };
                        if !resp.is_internal_fault() {
                            stats.record(resp.features_evaluated, total);
                            if browned {
                                resp.degraded = true;
                                stats.degraded.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        out.push(resp);
                    }
                    let _ = b.respond.send(out);
                    notifier.notify();
                }
            }
        }
    }
}

fn ensemble_worker(
    queue: &LaneQueue,
    ensemble: &EnsembleSnapshot,
    stats: &ServiceStats,
    depth: &AtomicUsize,
    max_batch: usize,
    seed: u64,
    notifier: &CompletionNotifier,
) {
    let mut scratch = ensemble.make_scratch(seed);
    let mut batch: Vec<Work> = Vec::with_capacity(max_batch);
    let dim = ensemble.dim();
    let voters = ensemble.voter_count();
    while queue.drain(&mut batch, max_batch) {
        depth.fetch_sub(batch.len(), Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        for work in batch.drain(..) {
            // Ensembles share one per-voter order stream across tiers, so
            // brownout cannot swap their tables without forking the
            // stream (documented limitation); deadlines and the degraded
            // flag still apply — a browned ensemble keeps scoring at full
            // attention but tells the client pressure is on.
            let browned = stats.tier.load(Ordering::Relaxed) > 0;
            match work.payload {
                Payload::One(req) => {
                    if dequeue_check(stats, work.enqueued, req.deadline) {
                        stats.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(ScoreResponse::deadline_exceeded());
                        notifier.notify();
                        continue;
                    }
                    // "Full evaluation" for the ensemble is every voter
                    // walking the whole support; early-exit accounting
                    // runs against that.
                    let (mut resp, total) = if req.kind.base() != ReqKind::Classify
                        || req.features.check_dim(dim).is_err()
                    {
                        (reject(), dim * voters)
                    } else {
                        let total = req.features.nnz() * voters;
                        let verbose = req.kind == ReqKind::ClassifyVerbose;
                        let outcome =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                crate::server::faultpoint::maybe_panic();
                                ensemble.classify_with(&req.features, &mut scratch, verbose)
                            }));
                        match outcome {
                            Ok(resp) => (resp, total),
                            Err(_) => {
                                stats.panics.fetch_add(1, Ordering::Relaxed);
                                // Scratch may be torn mid-vote: rebuild.
                                scratch = ensemble.make_scratch(seed);
                                (ScoreResponse::internal_fault(), total)
                            }
                        }
                    };
                    if !resp.is_internal_fault() {
                        stats.record(resp.features_evaluated, total);
                        if browned {
                            resp.degraded = true;
                            stats.degraded.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let _ = req.respond.send(resp);
                    notifier.notify();
                }
                Payload::Batch(b) => {
                    if dequeue_check(stats, work.enqueued, b.deadline) {
                        stats
                            .deadline_sheds
                            .fetch_add(b.examples.len() as u64, Ordering::Relaxed);
                        let out =
                            vec![ScoreResponse::deadline_exceeded(); b.examples.len()];
                        let _ = b.respond.send(out);
                        notifier.notify();
                        continue;
                    }
                    // Score batches are a binary-shard op; the hub
                    // screens the kind before admission, so this is the
                    // library-caller reject path, per example.
                    let mut out = Vec::with_capacity(b.examples.len());
                    for _ in &b.examples {
                        stats.record(0, dim * voters);
                        out.push(reject());
                    }
                    let _ = b.respond.send(out);
                    notifier.notify();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    #[test]
    fn easy_requests_exit_early() {
        let dim = 200;
        let (h, run) = PredictionService::new(model(dim), 8, 64, 0).spawn();
        let resp = h.score(vec![1.0; dim]).unwrap();
        assert!(resp.score > 0.0);
        assert!(resp.features_evaluated < dim / 4, "took {}", resp.features_evaluated);
        let resp_neg = h.score(vec![-1.0; dim]).unwrap();
        assert!(resp_neg.score < 0.0);
        let s = run.stats.snapshot();
        assert_eq!(s.served, 2);
        drop(h);
        run.join();
    }

    #[test]
    fn hard_requests_get_full_evaluation() {
        let dim = 64;
        let (h, run) = PredictionService::new(model(dim), 8, 64, 0).spawn();
        // Oscillating input: sign never certain until the end.
        let x: Vec<f64> = (0..dim).map(|i| if i % 2 == 0 { 0.01 } else { -0.01 }).collect();
        let resp = h.score(x).unwrap();
        assert_eq!(resp.features_evaluated, dim);
        drop(h);
        run.join();
    }

    #[test]
    fn dimension_mismatch_yields_nan() {
        let (h, run) = PredictionService::new(model(16), 4, 16, 0).spawn();
        let resp = h.score(vec![1.0; 3]).unwrap();
        assert!(resp.score.is_nan());
        assert_eq!(resp.features_evaluated, 0);
        drop(h);
        run.join();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let dim = 100;
        let (h, run) = PredictionService::new(model(dim), 16, 64, 1).with_workers(4).spawn();
        let answered: usize = std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for i in 0..8 {
                let h = h.clone();
                joins.push(scope.spawn(move || {
                    let mut ok = 0;
                    for j in 0..25 {
                        let sign = if (i + j) % 2 == 0 { 1.0 } else { -1.0 };
                        let r = h.score(vec![sign; dim]).unwrap();
                        assert!(!r.score.is_nan());
                        ok += 1;
                    }
                    ok
                }));
            }
            joins.into_iter().map(|j| j.join().unwrap()).sum()
        });
        assert_eq!(answered, 200);
        let s = run.stats.snapshot();
        assert_eq!(s.served, 200);
        assert!(s.avg_features() < dim as f64, "early exit should save features");
        drop(h);
        run.join();
    }

    #[test]
    fn snapshot_json_round_trip() {
        let m = model(4);
        let j = m.to_json().to_string_compact();
        let back = ModelSnapshot::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.weights, m.weights);
        assert_eq!(back.policy, m.policy);
        assert_eq!(back.boundary, m.boundary);
    }

    #[test]
    fn snapshot_round_trip_preserves_every_field() {
        let m = ModelSnapshot {
            weights: vec![0.25, -1.5, 0.0, 3.75e-3],
            var_sn: 12.5,
            boundary: AnyBoundary::Curved { delta: 0.05 },
            policy: CoordinatePolicy::WeightSampled,
        };
        let text = m.to_json().to_string_pretty();
        let back = ModelSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.weights, m.weights);
        assert_eq!(back.var_sn, m.var_sn);
        assert_eq!(back.boundary, m.boundary);
        assert_eq!(back.policy, m.policy);
    }

    #[test]
    fn snapshot_from_json_rejects_malformed_input() {
        let parse = |s: &str| ModelSnapshot::from_json(&Json::parse(s).unwrap());
        let good = model(2).to_json().to_string_compact();
        assert!(parse(&good).is_ok());

        // Missing weights.
        let e = parse(
            r#"{"var_sn":1,"boundary":{"kind":"full"},"policy":"sequential"}"#,
        )
        .unwrap_err();
        assert!(e.contains("weights"), "got {e:?}");

        // Non-numeric weight entry.
        let e = parse(
            r#"{"weights":[1,"x"],"var_sn":1,"boundary":{"kind":"full"},"policy":"sequential"}"#,
        )
        .unwrap_err();
        assert!(e.contains("non-numeric"), "got {e:?}");

        // Unknown policy name.
        let e = parse(
            r#"{"weights":[1],"var_sn":1,"boundary":{"kind":"full"},"policy":"psychic"}"#,
        )
        .unwrap_err();
        assert!(e.contains("psychic"), "got {e:?}");

        // Missing var_sn / boundary.
        assert!(parse(r#"{"weights":[1],"boundary":{"kind":"full"},"policy":"sequential"}"#)
            .is_err());
        assert!(parse(r#"{"weights":[1],"var_sn":1,"policy":"sequential"}"#).is_err());

        // Bad boundary kind bubbles up through AnyBoundary.
        assert!(parse(
            r#"{"weights":[1],"var_sn":1,"boundary":{"kind":"bogus"},"policy":"sequential"}"#
        )
        .is_err());
    }

    #[test]
    fn feature_bucket_edges() {
        assert_eq!(feature_bucket(0), 0);
        assert_eq!(feature_bucket(1), 1);
        assert_eq!(feature_bucket(2), 2);
        assert_eq!(feature_bucket(3), 2);
        assert_eq!(feature_bucket(4), 3);
        assert_eq!(feature_bucket(784), 10);
        assert_eq!(feature_bucket(1 << 20), FEATURE_BUCKETS - 1);
    }

    #[test]
    fn stats_histogram_percentiles_and_early_exit() {
        let stats = ServiceStats::default();
        // 90 requests stopping at 10 features, 10 running the full 784.
        for _ in 0..90 {
            stats.record(10, 784);
        }
        for _ in 0..10 {
            stats.record(784, 784);
        }
        let s = stats.snapshot();
        assert_eq!(s.served, 100);
        assert_eq!(s.early_exits, 90);
        assert!((s.early_exit_rate() - 0.9).abs() < 1e-12);
        assert!((s.avg_features() - (90.0 * 10.0 + 10.0 * 784.0) / 100.0).abs() < 1e-9);
        // p50 lands in the [8,16) bucket; p99 in the bucket holding 784.
        assert_eq!(s.feature_percentile(0.5), 15);
        assert_eq!(s.feature_percentile(0.99), 1023);
        assert_eq!(StatsSnapshot::default().feature_percentile(0.5), 0);
    }

    #[test]
    fn stats_snapshots_accumulate() {
        let a = ServiceStats::default();
        a.record(5, 100);
        let b = ServiceStats::default();
        b.record(100, 100);
        let mut total = a.snapshot();
        total.add(&b.snapshot());
        assert_eq!(total.served, 2);
        assert_eq!(total.features, 105);
        assert_eq!(total.early_exits, 1);
        assert_eq!(total.hist.iter().sum::<u64>(), 2);
    }

    #[test]
    fn full_queue_sheds_with_explicit_submit_error() {
        // One worker, one queue slot. Pin the worker on a ~1ms full
        // evaluation, then rapid-fire cheap requests: at most one can sit
        // in the queue, so the rest MUST come back `Overloaded` — load is
        // shed, not buffered.
        let dim = 1 << 20;
        let m = ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Full,
            policy: CoordinatePolicy::Sequential,
        };
        let (h, run) = PredictionService::new(m, 1, 1, 0).spawn();
        let big = h.submit(vec![0.5; dim]).expect("first request admitted");
        let mut admitted = Vec::new();
        let mut shed = 0;
        for _ in 0..10 {
            // Deliberately dim-mismatched: instant to build, and the
            // worker is busy anyway.
            match h.submit(Vec::<f64>::new()) {
                Ok(rx) => admitted.push(rx),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(SubmitError::Closed) => panic!("service alive"),
            }
        }
        assert!(shed >= 8, "a full bounded queue must shed, shed only {shed}/10");
        // Everything admitted is still answered.
        assert!(big.recv().unwrap().score > 0.0);
        for rx in admitted {
            rx.recv().unwrap();
        }
        drop(h);
        run.join();
    }

    #[test]
    fn sparse_request_scores_support_only() {
        let dim = 784;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        // 40 nonzeros out of 784: the walk must never exceed the support.
        let idx: Vec<u32> = (0..40u32).map(|i| i * 19).collect();
        let val = vec![1.0; 40];
        let resp = h.score(Features::Sparse { idx, val }).unwrap();
        assert!(resp.score > 0.0);
        assert!(resp.features_evaluated <= 40, "took {}", resp.features_evaluated);
        drop(h);
        run.join();
    }

    #[test]
    fn sparse_scoring_matches_dense_under_full_boundary() {
        // Sequential policy + Full boundary: the sparse walk must produce
        // the exact dense dot product (losslessness of the sparse path).
        let dim = 64;
        let m = ModelSnapshot {
            weights: (0..dim).map(|i| (i as f64 * 0.37).sin()).collect(),
            var_sn: 4.0,
            boundary: AnyBoundary::Full,
            policy: CoordinatePolicy::Sequential,
        };
        let (h, run) = PredictionService::new(m, 4, 16, 0).spawn();
        let mut dense = vec![0.0; dim];
        dense[3] = 0.5;
        dense[17] = -1.25;
        dense[40] = 2.0;
        let sparse = Features::sparsify(&dense, 0.0);
        let a = h.score(dense).unwrap();
        let b = h.score(sparse).unwrap();
        assert!((a.score - b.score).abs() < 1e-12, "dense {} vs sparse {}", a.score, b.score);
        assert_eq!(b.features_evaluated, 3, "full boundary walks the whole support");
        drop(h);
        run.join();
    }

    #[test]
    fn sparse_out_of_range_index_yields_nan() {
        let (h, run) = PredictionService::new(model(16), 4, 16, 0).spawn();
        let resp = h
            .score(Features::Sparse { idx: vec![3, 99], val: vec![1.0, 1.0] })
            .unwrap();
        assert!(resp.score.is_nan());
        drop(h);
        run.join();
    }

    #[test]
    fn features_validate_and_round_trip() {
        let dense = Features::Dense(vec![0.0, 1.5, 0.0, -2.0]);
        dense.validate().unwrap();
        let sparse = Features::sparsify(&[0.0, 1.5, 0.0, -2.0], 0.0);
        sparse.validate().unwrap();
        assert_eq!(sparse.nnz(), 2);
        assert_eq!(sparse.densify(4), vec![0.0, 1.5, 0.0, -2.0]);
        match &sparse {
            Features::Sparse { idx, val } => {
                assert_eq!(idx, &[1, 3]);
                assert_eq!(val, &[1.5, -2.0]);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Threshold sparsification drops small entries.
        let thinned = Features::sparsify(&[0.01, 1.5, -0.02, -2.0], 0.1);
        assert_eq!(thinned.nnz(), 2);

        // Structural rejections.
        assert!(Features::Sparse { idx: vec![1], val: vec![1.0, 2.0] }.validate().is_err());
        assert!(Features::Sparse { idx: vec![2, 2], val: vec![1.0, 2.0] }.validate().is_err());
        assert!(Features::Sparse { idx: vec![3, 1], val: vec![1.0, 2.0] }.validate().is_err());
        assert!(Features::Sparse { idx: vec![1], val: vec![f64::NAN] }.validate().is_err());
        assert!(Features::Dense(vec![1.0, f64::INFINITY]).validate().is_err());

        // Dim checks.
        assert!(Features::Dense(vec![0.0; 4]).check_dim(4).is_ok());
        assert_eq!(Features::Dense(vec![0.0; 3]).check_dim(4), Err((4, 3)));
        assert!(Features::Sparse { idx: vec![3], val: vec![1.0] }.check_dim(4).is_ok());
        assert_eq!(
            Features::Sparse { idx: vec![9], val: vec![1.0] }.check_dim(4),
            Err((4, 10))
        );
        // Unsorted garbage (library callers can bypass the wire
        // parsers): the screen must still catch the out-of-range
        // middle index, not just trust the last one.
        assert_eq!(
            Features::Sparse { idx: vec![9999, 2], val: vec![1.0, 1.0] }.check_dim(784),
            Err((784, 10_000))
        );
        assert!(Features::Sparse { idx: vec![], val: vec![] }.check_dim(4).is_ok());
    }

    /// Flat deterministic 3-class ensemble: every voter's weights are
    /// all `+1`, so a positive input makes every voter vote its `pos`
    /// class (votes 0:2, 1:1, 2:0 → label 0) and a negative input its
    /// `neg` class (votes 1:1, 2:2 → label 2).
    fn flat_ensemble(dim: usize) -> EnsembleSnapshot {
        let classes = vec![0i64, 1, 2];
        let mut voters = Vec::new();
        for a in 0..classes.len() {
            for b in a + 1..classes.len() {
                voters.push(VoterSnapshot {
                    pos: classes[a],
                    neg: classes[b],
                    weights: vec![1.0; dim],
                    var_sn: 4.0,
                });
            }
        }
        EnsembleSnapshot {
            classes,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
            voters,
        }
    }

    #[test]
    fn ensemble_classify_votes_deterministically_and_early_exits() {
        let dim = 64;
        let ens = flat_ensemble(dim);
        assert_eq!(ens.dim(), dim);
        assert_eq!(ens.voter_count(), 3);
        let mut scratch = ens.make_scratch(0);
        let up = ens.classify(&Features::Dense(vec![1.0; dim]), &mut scratch);
        let info = up.classify.expect("classify outcome");
        assert_eq!(info.label, 0);
        assert_eq!(info.votes, 2);
        assert_eq!(info.voters, 3);
        assert_eq!(up.score, 2.0, "score carries the winning vote count");
        assert!(
            up.features_evaluated < 3 * dim,
            "voters must early-exit, spent {}",
            up.features_evaluated
        );
        let down = ens.classify(&Features::Dense(vec![-1.0; dim]), &mut scratch);
        assert_eq!(down.classify.unwrap().label, 2);
        // Sparse payloads walk only the support, per voter.
        let sparse =
            ens.classify(&Features::Sparse { idx: vec![3, 9], val: vec![1.0, 1.0] }, &mut scratch);
        assert_eq!(sparse.classify.unwrap().label, 0);
        assert!(sparse.features_evaluated <= 6, "3 voters × nnz 2 caps the walk");
    }

    #[test]
    fn verbose_classify_attributes_cost_per_voter_without_changing_the_vote() {
        let dim = 64;
        let ens = flat_ensemble(dim);
        let x = Features::Dense(vec![1.0; dim]);
        // Two independent scratch sets so the verbose run replays the
        // exact same policy stream as the plain one.
        let mut scratch_a = ens.make_scratch(7);
        let mut scratch_b = ens.make_scratch(7);
        let plain = ens.classify(&x, &mut scratch_a);
        assert!(plain.per_voter.is_none(), "plain classify carries no breakdown");
        let verbose = ens.classify_with(&x, &mut scratch_b, true);
        assert_eq!(plain.classify, verbose.classify);
        assert_eq!(plain.features_evaluated, verbose.features_evaluated);
        let rows = verbose.per_voter.expect("verbose breakdown");
        assert_eq!(rows.len(), 3);
        // Pair-enumeration order, and each row's vote is one of its pair.
        assert_eq!((rows[0].pos, rows[0].neg), (0, 1));
        assert_eq!((rows[1].pos, rows[1].neg), (0, 2));
        assert_eq!((rows[2].pos, rows[2].neg), (1, 2));
        for row in &rows {
            assert!(row.vote == row.pos || row.vote == row.neg);
            assert_eq!(row.vote, row.pos, "all-(+1) voters vote pos on a positive input");
        }
        // The rows decompose the total exactly.
        let sum: usize = rows.iter().map(|r| r.features as usize).sum();
        assert_eq!(sum, verbose.features_evaluated);
        // And the kind plumbing: a verbose submit through the service.
        let (h, run) = PredictionService::new(flat_ensemble(dim), 4, 16, 0).spawn();
        let rx = h.submit_kind(vec![1.0; dim], ReqKind::ClassifyVerbose).unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.classify.unwrap().label, 0);
        assert_eq!(resp.per_voter.expect("breakdown over the service").len(), 3);
        // Non-verbose submits stay lean.
        let resp = h.classify(vec![1.0; dim]).unwrap();
        assert!(resp.per_voter.is_none());
        drop(h);
        run.join();
        assert_eq!(ReqKind::ClassifyVerbose.base(), ReqKind::Classify);
        assert_eq!(ReqKind::ClassifyVerbose.name(), "classify");
        assert_eq!(ReqKind::Score.base(), ReqKind::Score);
    }

    #[test]
    fn ensemble_snapshot_json_round_trip_and_validation() {
        let ens = flat_ensemble(4);
        let text = ens.to_json().to_string_compact();
        let back = EnsembleSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.classes, ens.classes);
        assert_eq!(back.voter_count(), 3);
        assert_eq!(back.voters[1].pos, 0);
        assert_eq!(back.voters[1].neg, 2);
        assert_eq!(back.voters[0].weights, vec![1.0; 4]);

        // ServingModel dispatches on the `voters` field.
        match ServingModel::from_json(&Json::parse(&text).unwrap()).unwrap() {
            ServingModel::Ensemble(e) => assert_eq!(e.dim(), 4),
            other => panic!("expected ensemble, got {}", other.kind_name()),
        }
        let binary = model(4).to_json().to_string_compact();
        match ServingModel::from_json(&Json::parse(&binary).unwrap()).unwrap() {
            ServingModel::Binary(m) => assert_eq!(m.weights.len(), 4),
            other => panic!("expected binary, got {}", other.kind_name()),
        }

        // Structural rejections.
        let parse = |s: &str| EnsembleSnapshot::from_json(&Json::parse(s).unwrap());
        let mut one_class = ens.clone();
        one_class.classes = vec![7];
        assert!(parse(&one_class.to_json().to_string_compact()).is_err(), "one class");
        let mut missing_voter = ens.clone();
        missing_voter.voters.pop();
        assert!(parse(&missing_voter.to_json().to_string_compact()).is_err(), "voter count");
        let mut swapped = ens.clone();
        swapped.voters.swap(0, 1);
        assert!(parse(&swapped.to_json().to_string_compact()).is_err(), "pair order");
        let mut ragged = ens.clone();
        ragged.voters[2].weights.push(0.0);
        assert!(parse(&ragged.to_json().to_string_compact()).is_err(), "ragged dims");
    }

    #[test]
    fn ensemble_service_classifies_and_rejects_wrong_kind() {
        let dim = 32;
        let (h, run) = PredictionService::new(flat_ensemble(dim), 4, 16, 0).spawn();
        let resp = h.classify(vec![1.0; dim]).unwrap();
        assert_eq!(resp.classify.unwrap().label, 0);
        // A score request against an ensemble shard is the worker-level
        // reject sentinel (the hub screens this before admission).
        let resp = h.score(vec![1.0; dim]).unwrap();
        assert!(resp.score.is_nan());
        assert!(resp.classify.is_none());
        // And classify against a binary shard likewise.
        drop(h);
        run.join();
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        let resp = h.classify(vec![1.0; dim]).unwrap();
        assert!(resp.score.is_nan());
        drop(h);
        run.join();
    }

    #[test]
    fn completion_notifier_fires_once_per_response() {
        let fired = Arc::new(AtomicU64::new(0));
        let count = Arc::clone(&fired);
        let notifier = CompletionNotifier::new(move || {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert!(notifier.is_active());
        assert!(!CompletionNotifier::default().is_active());
        CompletionNotifier::default().notify(); // no-op, must not panic
        let dim = 16;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0)
            .with_notifier(notifier)
            .spawn();
        for _ in 0..5 {
            h.score(vec![1.0; dim]).unwrap();
        }
        drop(h);
        run.join();
        assert_eq!(fired.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn submit_is_nonblocking_and_answers() {
        let dim = 32;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        let rx = h.submit(vec![1.0; dim]).expect("queue has room");
        let resp = rx.recv().expect("admitted requests are always answered");
        assert!(resp.score > 0.0);
        drop(h);
        run.join();
    }

    /// Mixed test payloads: confident, ambiguous, and sparse examples.
    fn batch_examples(dim: usize, k: usize) -> Vec<Features> {
        (0..k)
            .map(|i| match i % 3 {
                0 => Features::Dense(vec![if i % 2 == 0 { 1.0 } else { -1.0 }; dim]),
                1 => Features::Dense(
                    (0..dim).map(|j| if (i + j) % 2 == 0 { 0.01 } else { -0.01 }).collect(),
                ),
                _ => Features::Sparse {
                    idx: (0..dim as u32 / 4).map(|j| j * 3).collect(),
                    val: (0..dim / 4).map(|j| ((i + j) as f64 * 0.7).sin()).collect(),
                },
            })
            .collect()
    }

    #[test]
    fn batch_is_bit_identical_to_singles() {
        // The same examples through one Work::Batch and through k
        // sequential singles, against two services with the same seed
        // and a single worker each: every (score, features_evaluated)
        // pair must match exactly — same order-generator stream, same
        // thresholds, same FP association.
        let dim = 64;
        let examples = batch_examples(dim, 9);
        let (h_batch, run_batch) = PredictionService::new(model(dim), 8, 64, 42).spawn();
        let (h_single, run_single) = PredictionService::new(model(dim), 8, 64, 42).spawn();
        let batched = h_batch.submit_batch(examples.clone()).unwrap().recv().unwrap();
        assert_eq!(batched.len(), examples.len());
        for (i, features) in examples.iter().enumerate() {
            let single = h_single.score(features.clone()).unwrap();
            assert_eq!(batched[i].score, single.score, "example {i} score");
            assert_eq!(
                batched[i].features_evaluated, single.features_evaluated,
                "example {i} feature count"
            );
        }
        // Early-exit stats identical too (one extra `batches` tick is
        // the design: the whole batch was one drain unit).
        let sb = run_batch.stats.snapshot();
        let ss = run_single.stats.snapshot();
        assert_eq!(sb.served, ss.served);
        assert_eq!(sb.features, ss.features);
        assert_eq!(sb.early_exits, ss.early_exits);
        assert_eq!(sb.hist, ss.hist);
        drop(h_batch);
        drop(h_single);
        run_batch.join();
        run_single.join();
    }

    #[test]
    fn batch_bad_example_rejects_alone() {
        let dim = 16;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        let examples = vec![
            Features::Dense(vec![1.0; dim]),
            Features::Dense(vec![1.0; 3]), // wrong dim
            Features::Sparse { idx: vec![2, 99], val: vec![1.0, 1.0] }, // out of range
            Features::Dense(vec![-1.0; dim]),
        ];
        let out = h.submit_batch(examples).unwrap().recv().unwrap();
        assert_eq!(out.len(), 4);
        assert!(out[0].score > 0.0);
        assert!(out[1].score.is_nan(), "dim mismatch rejects in place");
        assert!(out[2].score.is_nan(), "out-of-range index rejects in place");
        assert!(out[3].score < 0.0, "later examples are unaffected");
        drop(h);
        run.join();
    }

    #[test]
    fn batch_against_ensemble_rejects_per_example() {
        let dim = 16;
        let (h, run) = PredictionService::new(flat_ensemble(dim), 4, 16, 0).spawn();
        let out = h
            .submit_batch(vec![Features::Dense(vec![1.0; dim]); 3])
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.score.is_nan()), "score batch needs a binary shard");
        drop(h);
        run.join();
    }

    /// A throwaway interactive work unit for direct [`LaneQueue`] tests.
    fn lane_unit(interactive: bool) -> Work {
        let payload = if interactive {
            let (tx, _rx) = sync_channel(1);
            Payload::One(ScoreRequest {
                features: Features::Dense(vec![1.0]),
                kind: ReqKind::Score,
                deadline: None,
                respond: tx,
            })
        } else {
            let (tx, _rx) = sync_channel(1);
            Payload::Batch(BatchRequest { examples: Vec::new(), deadline: None, respond: tx })
        };
        Work { payload, enqueued: Instant::now() }
    }

    #[test]
    fn weighted_dequeue_prefers_interactive_without_starving_bulk() {
        let q = LaneQueue::new(32);
        for _ in 0..8 {
            assert!(q.try_push(lane_unit(true), Lane::Interactive).is_ok());
        }
        for _ in 0..8 {
            assert!(q.try_push(lane_unit(false), Lane::Bulk).is_ok());
        }
        let mut batch = Vec::new();
        assert!(q.drain(&mut batch, 16));
        assert_eq!(batch.len(), 16);
        let picks: Vec<bool> = batch
            .iter()
            .map(|w| matches!(w.payload, Payload::Batch(_)))
            .collect();
        // Interactive overtakes queued bulk, but every BULK_EVERY-th
        // pick serves the bulk lane while both are non-empty; once
        // interactive is dry, the remaining bulk drains straight out.
        let expected_bulk = [3usize, 7, 10, 11, 12, 13, 14, 15];
        for (i, &is_bulk) in picks.iter().enumerate() {
            assert_eq!(is_bulk, expected_bulk.contains(&i), "pick {i} of {picks:?}");
        }
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue_not_scored() {
        let dim = 32;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        // A deadline stamped before submission has always expired by
        // dequeue time (monotonic clock, `now >= deadline` sheds).
        let past = SubmitOpts { deadline: Some(Instant::now()), lane: None };
        let resp = h
            .submit_opts(vec![1.0; dim], ReqKind::Score, past)
            .unwrap()
            .recv()
            .unwrap();
        assert!(resp.is_deadline_exceeded());
        assert!(resp.score.is_nan());
        assert!(!resp.is_internal_fault(), "distinct sentinel from internal faults");
        // A whole expired batch answers the sentinel in every slot and
        // counts one shed per example.
        let out = h
            .submit_batch_opts(
                batch_examples(dim, 3),
                SubmitOpts { deadline: Some(Instant::now()), lane: None },
            )
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.is_deadline_exceeded()));
        // A generous deadline scores normally — the common no-pressure case.
        let future = SubmitOpts {
            deadline: Some(Instant::now() + Duration::from_secs(3600)),
            lane: None,
        };
        let resp = h
            .submit_opts(vec![1.0; dim], ReqKind::Score, future)
            .unwrap()
            .recv()
            .unwrap();
        assert!(resp.score > 0.0);
        drop(h);
        run.join();
        let s = run.stats.snapshot();
        assert_eq!(s.deadline_sheds, 4, "1 single + 3 batch slots");
        assert_eq!(s.served, 1, "shed work never reaches the scorer");
    }

    #[test]
    fn brownout_controller_climbs_and_recovers_with_hysteresis() {
        let capacity = 8;
        let q = Arc::new(LaneQueue::new(capacity));
        let stats = Arc::new(ServiceStats::default());
        let depth = Arc::new(AtomicUsize::new(capacity)); // occupancy 1.0
        let cfg = BrownoutConfig {
            tighten: 0.5,
            enter: 0.75,
            exit: 0.35,
            dwell_ms: 5,
            sample_ms: 1,
            latency_target_us: 0,
        };
        let (qc, sc, dc) = (q.clone(), stats.clone(), depth.clone());
        let t = std::thread::spawn(move || brownout_controller(&qc, &sc, &dc, capacity, &cfg));
        let wait_for = |what: &str, cond: &dyn Fn() -> bool| {
            let start = Instant::now();
            while !cond() {
                assert!(start.elapsed() < Duration::from_secs(10), "timed out waiting: {what}");
                std::thread::sleep(Duration::from_millis(2));
            }
        };
        // Sustained saturation walks the gauge one dwell at a time up to
        // the shed tier, which flips bulk shedding on.
        wait_for("tier 3", &|| stats.tier.load(Ordering::Relaxed) == MAX_TIER);
        assert!(q.shed_bulk.load(Ordering::Relaxed));
        // Pressure release walks it back down and re-opens the bulk lane.
        depth.store(0, Ordering::Relaxed);
        wait_for("tier 0", &|| stats.tier.load(Ordering::Relaxed) == 0);
        assert!(!q.shed_bulk.load(Ordering::Relaxed));
        assert!(
            stats.tier_transitions.load(Ordering::Relaxed) >= 6,
            "3 steps up + 3 steps down"
        );
        lane_lock(&q).closed = true;
        t.join().unwrap();
    }

    fn budgeted_model(dim: usize, k: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Budgeted { k },
            policy: CoordinatePolicy::Sequential,
        }
    }

    /// The brownout config used by tests that force the tier gauge by
    /// hand: `enter` at 1.0 is unreachable (pressure is capped at 1.0
    /// and must strictly exceed it), so the controller never moves the
    /// gauge on its own.
    fn inert_brownout(tighten: f64) -> BrownoutConfig {
        BrownoutConfig {
            tighten,
            enter: 1.0,
            exit: 0.01,
            dwell_ms: 1,
            sample_ms: 1,
            latency_target_us: 0,
        }
    }

    #[test]
    fn brown_tiers_cut_features_evaluated_and_flag_degraded() {
        let dim = 64;
        // Oscillating input never crosses a boundary, so a budget-48
        // walk runs to its cap — the feature spend per tier is exact:
        // 48, then 48·0.5 = 24, then 48·0.25 = 12.
        let hard: Vec<f64> = (0..dim).map(|i| if i % 2 == 0 { 0.01 } else { -0.01 }).collect();
        let (h, run) = PredictionService::new(budgeted_model(dim, 48), 4, 16, 0)
            .with_brownout(Some(inert_brownout(0.5)))
            .spawn();
        let resp = h.score(hard.clone()).unwrap();
        assert_eq!(resp.features_evaluated, 48, "tier 0 scores at the plain budget");
        assert!(!resp.degraded);
        run.stats.tier.store(1, Ordering::Relaxed);
        let resp = h.score(hard.clone()).unwrap();
        assert_eq!(resp.features_evaluated, 24, "brown-1 halves the budget");
        assert!(resp.degraded);
        // Tiers past the table set (shed keeps scoring survivors) clamp
        // to the deepest brown table.
        run.stats.tier.store(MAX_TIER, Ordering::Relaxed);
        let resp = h.score(hard).unwrap();
        assert_eq!(resp.features_evaluated, 12, "tier 3 clamps to the tighten² table");
        assert!(resp.degraded);
        drop(h);
        run.join();
        let s = run.stats.snapshot();
        assert_eq!(s.degraded, 2, "only brown-tier answers count as degraded");
        assert_eq!(s.served, 3);
    }

    #[test]
    fn brownout_disabled_and_tier_zero_are_bit_identical() {
        let dim = 64;
        let examples = batch_examples(dim, 9);
        let (h_plain, run_plain) = PredictionService::new(model(dim), 8, 64, 42).spawn();
        let (h_brown, run_brown) = PredictionService::new(model(dim), 8, 64, 42)
            .with_brownout(Some(inert_brownout(0.5)))
            .spawn();
        for (i, features) in examples.iter().enumerate() {
            let a = h_plain.score(features.clone()).unwrap();
            let b = h_brown.score(features.clone()).unwrap();
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "example {i} score");
            assert_eq!(a.features_evaluated, b.features_evaluated, "example {i} spend");
            assert!(!b.degraded, "tier 0 answers are never flagged");
        }
        drop(h_plain);
        drop(h_brown);
        run_plain.join();
        run_brown.join();
        let (sp, sb) = (run_plain.stats.snapshot(), run_brown.stats.snapshot());
        assert_eq!(sp.features, sb.features);
        assert_eq!(sp.hist, sb.hist);
        assert_eq!(sb.degraded, 0);
        assert_eq!(sb.tier_transitions, 0);
    }

    #[test]
    fn shed_tier_rejects_bulk_admissions_but_keeps_interactive() {
        let dim = 16;
        let (h, run) = PredictionService::new(model(dim), 4, 16, 0).spawn();
        h.queue.set_shed_bulk(true);
        let err = h.submit_batch(batch_examples(dim, 2)).unwrap_err();
        assert!(matches!(err, SubmitError::Overloaded), "bulk is shed, not buffered");
        let (load, _) = h.queue_load();
        assert_eq!(load, 0, "rejected batch rolls its depth bump back");
        // Interactive singles — including the blocking path — still land.
        assert!(h.score(vec![1.0; dim]).unwrap().score > 0.0);
        // A lane override routes a batch around the shed.
        let out = h
            .submit_batch_opts(
                batch_examples(dim, 2),
                SubmitOpts { deadline: None, lane: Some(Lane::Interactive) },
            )
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(out.len(), 2);
        h.queue.set_shed_bulk(false);
        let out = h.submit_batch(batch_examples(dim, 2)).unwrap().recv().unwrap();
        assert_eq!(out.len(), 2);
        drop(h);
        run.join();
    }
}
