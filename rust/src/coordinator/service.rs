//! Threaded prediction service with attentive early-exit.
//!
//! A model-server-style serving loop: requests (feature vectors) arrive
//! on an mpsc queue, worker threads drain up to `max_batch` requests at a
//! time (dynamic batching without a timer: lowest latency at low load,
//! full batches under pressure), and each example is scored with the
//! **early-stopped predictor** — easy inputs exit after a handful of
//! features, hard ones get the full evaluation. The paper's
//! focus-of-attention becomes a serving-latency mechanism: average
//! feature cost (≈ service time) scales with input difficulty, not
//! dimensionality.
//!
//! Python is never involved: the model is a plain weight vector (trained
//! by the coordinator or loaded from a JSON snapshot) and the hot loop is
//! pure rust.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::learner::predictor::EarlyStopPredictor;
use crate::margin::policy::{CoordinatePolicy, OrderGenerator};
use crate::stst::boundary::AnyBoundary;
use crate::util::json::Json;

/// Immutable model snapshot served by the service.
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    /// Weight vector.
    pub weights: Vec<f64>,
    /// Full-sum variance estimate used by the prediction boundary
    /// (max over the two classes, conservative).
    pub var_sn: f64,
    /// Boundary the service applies at prediction time.
    pub boundary: AnyBoundary,
    /// Coordinate policy for the prediction walks.
    pub policy: CoordinatePolicy,
}

impl ModelSnapshot {
    /// Serialize (for `attentive serve --snapshot`).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("weights", Json::Arr(self.weights.iter().map(|&w| Json::Num(w)).collect())),
            ("var_sn", Json::Num(self.var_sn)),
            ("boundary", self.boundary.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
        ])
    }

    /// Parse the form produced by [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<Self, String> {
        Ok(Self {
            weights: v
                .get("weights")
                .and_then(|a| a.as_arr())
                .ok_or("snapshot: missing weights")?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| "snapshot: non-numeric weight".to_string()))
                .collect::<Result<_, _>>()?,
            var_sn: v.get("var_sn").and_then(|x| x.as_f64()).ok_or("snapshot: missing var_sn")?,
            boundary: AnyBoundary::from_json(v.get("boundary").ok_or("snapshot: missing boundary")?)?,
            policy: CoordinatePolicy::from_name(
                v.get("policy").and_then(|s| s.as_str()).ok_or("snapshot: missing policy")?,
            )?,
        })
    }
}

/// One scoring request (internal envelope).
struct ScoreRequest {
    features: Vec<f64>,
    respond: SyncSender<ScoreResponse>,
}

/// Scoring result.
#[derive(Debug, Clone, Copy)]
pub struct ScoreResponse {
    /// Signed margin estimate; the prediction is its sign.
    pub score: f64,
    /// Features evaluated before the early exit (≤ dim).
    pub features_evaluated: usize,
}

/// Live service counters (lock-free reads).
#[derive(Debug, Default)]
pub struct ServiceStats {
    served: AtomicU64,
    features: AtomicU64,
    batches: AtomicU64,
}

/// A snapshot of [`ServiceStats`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsSnapshot {
    /// Requests served.
    pub served: u64,
    /// Total features evaluated.
    pub features: u64,
    /// Batches drained.
    pub batches: u64,
}

impl StatsSnapshot {
    /// Mean features per request.
    pub fn avg_features(&self) -> f64 {
        if self.served == 0 { 0.0 } else { self.features as f64 / self.served as f64 }
    }
}

impl ServiceStats {
    /// Read the counters.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            served: self.served.load(Ordering::Relaxed),
            features: self.features.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
        }
    }
}

/// Handle for submitting requests to a running service. Cloneable;
/// dropping every handle shuts the workers down.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<ScoreRequest>,
}

impl ServiceHandle {
    /// Score one feature vector, blocking until the result arrives.
    /// Returns `None` if the service has shut down or the queue is
    /// persistently full (backpressure).
    pub fn score(&self, features: Vec<f64>) -> Option<ScoreResponse> {
        let (tx, rx) = sync_channel(1);
        match self.tx.try_send(ScoreRequest { features, respond: tx }) {
            Ok(()) => {}
            Err(TrySendError::Full(req)) => {
                // Block on a full queue (backpressure) rather than dropping.
                self.tx.send(req).ok()?;
            }
            Err(TrySendError::Disconnected(_)) => return None,
        }
        rx.recv().ok()
    }
}

/// The prediction service: owns the model and the batching workers.
pub struct PredictionService {
    model: Arc<ModelSnapshot>,
    /// Max requests drained per batch.
    pub max_batch: usize,
    /// Queue capacity (backpressure bound).
    pub queue: usize,
    /// Worker threads.
    pub workers: usize,
    seed: u64,
}

/// A running service: join handles + stats.
pub struct RunningService {
    /// Shared counters.
    pub stats: Arc<ServiceStats>,
    handles: Vec<JoinHandle<()>>,
}

impl RunningService {
    /// Wait for workers to finish (after all [`ServiceHandle`]s drop).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

impl PredictionService {
    /// Service over a model snapshot.
    pub fn new(model: ModelSnapshot, max_batch: usize, queue: usize, seed: u64) -> Self {
        Self {
            model: Arc::new(model),
            max_batch: max_batch.max(1),
            queue: queue.max(1),
            workers: 1,
            seed,
        }
    }

    /// Use `n` worker threads.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Start the workers. Returns a request handle and the running
    /// service (stats + joins).
    pub fn spawn(self) -> (ServiceHandle, RunningService) {
        let (tx, rx) = sync_channel::<ScoreRequest>(self.queue);
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(ServiceStats::default());
        let mut handles = Vec::new();
        for worker_id in 0..self.workers {
            let rx = rx.clone();
            let model = self.model.clone();
            let stats = stats.clone();
            let max_batch = self.max_batch;
            let seed = self.seed ^ (worker_id as u64) << 32;
            handles.push(std::thread::spawn(move || {
                worker_loop(rx, model, stats, max_batch, seed)
            }));
        }
        (ServiceHandle { tx }, RunningService { stats, handles })
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<ScoreRequest>>>,
    model: Arc<ModelSnapshot>,
    stats: Arc<ServiceStats>,
    max_batch: usize,
    seed: u64,
) {
    let mut orders = OrderGenerator::new(model.policy, seed);
    orders.refresh(&model.weights);
    let mut batch: Vec<ScoreRequest> = Vec::with_capacity(max_batch);
    loop {
        // Blocking receive for the first request, opportunistic drain for
        // the rest — dynamic batching without a timer.
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(first) => batch.push(first),
                Err(_) => return, // all senders dropped
            }
            while batch.len() < max_batch {
                match guard.try_recv() {
                    Ok(req) => batch.push(req),
                    Err(_) => break,
                }
            }
        } // release the lock before compute
        stats.batches.fetch_add(1, Ordering::Relaxed);
        for req in batch.drain(..) {
            let resp = if req.features.len() != model.weights.len() {
                ScoreResponse { score: f64::NAN, features_evaluated: 0 }
            } else {
                let predictor = EarlyStopPredictor::new(&model.boundary);
                let order = orders.next();
                let (score, k) =
                    predictor.predict(&model.weights, &req.features, order, model.var_sn);
                ScoreResponse { score, features_evaluated: k }
            };
            stats.served.fetch_add(1, Ordering::Relaxed);
            stats.features.fetch_add(resp.features_evaluated as u64, Ordering::Relaxed);
            let _ = req.respond.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    #[test]
    fn easy_requests_exit_early() {
        let dim = 200;
        let (h, run) = PredictionService::new(model(dim), 8, 64, 0).spawn();
        let resp = h.score(vec![1.0; dim]).unwrap();
        assert!(resp.score > 0.0);
        assert!(resp.features_evaluated < dim / 4, "took {}", resp.features_evaluated);
        let resp_neg = h.score(vec![-1.0; dim]).unwrap();
        assert!(resp_neg.score < 0.0);
        let s = run.stats.snapshot();
        assert_eq!(s.served, 2);
        drop(h);
        run.join();
    }

    #[test]
    fn hard_requests_get_full_evaluation() {
        let dim = 64;
        let (h, run) = PredictionService::new(model(dim), 8, 64, 0).spawn();
        // Oscillating input: sign never certain until the end.
        let x: Vec<f64> = (0..dim).map(|i| if i % 2 == 0 { 0.01 } else { -0.01 }).collect();
        let resp = h.score(x).unwrap();
        assert_eq!(resp.features_evaluated, dim);
        drop(h);
        run.join();
    }

    #[test]
    fn dimension_mismatch_yields_nan() {
        let (h, run) = PredictionService::new(model(16), 4, 16, 0).spawn();
        let resp = h.score(vec![1.0; 3]).unwrap();
        assert!(resp.score.is_nan());
        assert_eq!(resp.features_evaluated, 0);
        drop(h);
        run.join();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let dim = 100;
        let (h, run) = PredictionService::new(model(dim), 16, 64, 1).with_workers(4).spawn();
        let answered: usize = std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for i in 0..8 {
                let h = h.clone();
                joins.push(scope.spawn(move || {
                    let mut ok = 0;
                    for j in 0..25 {
                        let sign = if (i + j) % 2 == 0 { 1.0 } else { -1.0 };
                        let r = h.score(vec![sign; dim]).unwrap();
                        assert!(!r.score.is_nan());
                        ok += 1;
                    }
                    ok
                }));
            }
            joins.into_iter().map(|j| j.join().unwrap()).sum()
        });
        assert_eq!(answered, 200);
        let s = run.stats.snapshot();
        assert_eq!(s.served, 200);
        assert!(s.avg_features() < dim as f64, "early exit should save features");
        drop(h);
        run.join();
    }

    #[test]
    fn snapshot_json_round_trip() {
        let m = model(4);
        let j = m.to_json().to_string_compact();
        let back = ModelSnapshot::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.weights, m.weights);
        assert_eq!(back.policy, m.policy);
        assert_eq!(back.boundary, m.boundary);
    }
}
