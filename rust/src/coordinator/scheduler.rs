//! Multi-run / multi-task parallel scheduler.
//!
//! The paper reports every curve as the average of 10 runs over different
//! dataset permutations, for three algorithms, under three coordinate
//! policies — a 90-run grid per figure. [`run_sweep`] executes such grids
//! with rayon, one task per (config, run) cell, aggregating per-config
//! mean curves and summary rows. Determinism: cell seeds derive from
//! `(config seed, run index)` only, so results are independent of thread
//! scheduling.


use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::metrics::curve::Curve;

use super::factory;
use super::trainer::{TrainReport, Trainer, TrainerConfig};

/// Aggregated result of all runs of one experiment config.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Config name.
    pub name: String,
    /// Learner identity (from the first run).
    pub learner: String,
    /// Per-run reports.
    pub runs: Vec<TrainReport>,
    /// Mean features curve across runs.
    pub mean_features: Curve,
    /// Mean test-error curve across runs.
    pub mean_test_error: Curve,
    /// Mean of final test errors (full prediction).
    pub final_test_error: f64,
    /// Mean of final test errors (early-stopped prediction).
    pub final_test_error_early: f64,
    /// Mean avg-features per training example.
    pub avg_features: f64,
    /// Mean avg-features per early-stopped prediction.
    pub predict_avg_features: f64,
}

impl SweepOutcome {
    fn from_runs(name: String, runs: Vec<TrainReport>) -> Self {
        let n = runs.len().max(1) as f64;
        let feats: Vec<Curve> = runs.iter().map(|r| r.features_curve.clone()).collect();
        let errs: Vec<Curve> = runs.iter().map(|r| r.test_error_curve.clone()).collect();
        SweepOutcome {
            learner: runs.first().map(|r| r.learner.clone()).unwrap_or_default(),
            mean_features: Curve::mean(format!("{name}/features"), &feats),
            mean_test_error: Curve::mean(format!("{name}/test-error"), &errs),
            final_test_error: runs.iter().map(|r| r.final_test_error).sum::<f64>() / n,
            final_test_error_early: runs.iter().map(|r| r.final_test_error_early).sum::<f64>()
                / n,
            avg_features: runs.iter().map(|r| r.avg_features_per_example()).sum::<f64>() / n,
            predict_avg_features: runs.iter().map(|r| r.predict_avg_features).sum::<f64>() / n,
            name,
            runs,
        }
    }

    /// Speedup vs full computation on the training stream.
    pub fn speedup(&self, dim: usize) -> f64 {
        if self.avg_features == 0.0 { 1.0 } else { dim as f64 / self.avg_features }
    }
}

/// Execute one experiment config: `cfg.runs` independent (permutation,
/// seed) runs in parallel, aggregated.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<SweepOutcome> {
    cfg.validate()?;
    let (train, test) = factory::build_task(cfg)?;
    let run_ids: Vec<u64> = (0..cfg.runs).collect();
    let runs: Vec<TrainReport> = crate::util::parallel::par_map(&run_ids, |&run| {
            let mut learner = factory::build_learner(cfg, train.dim(), run);
            let trainer = Trainer::new(TrainerConfig {
                epochs: cfg.epochs,
                eval_every: cfg.eval_every,
                seed: cfg.seed ^ run.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                audit: cfg.audit,
                curves: true,
            });
            trainer.fit_eval(learner.as_mut(), &train, Some(&test))
    });
    Ok(SweepOutcome::from_runs(cfg.name.clone(), runs))
}

/// Execute a grid of configs (each with its internal runs), configs in
/// sequence, runs in parallel. Returns outcomes in input order.
pub fn run_sweep(configs: &[ExperimentConfig]) -> Result<Vec<SweepOutcome>> {
    configs.iter().map(run_experiment).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;
    use crate::stst::boundary::AnyBoundary;

    fn quick_cfg(name: &str, boundary: AnyBoundary) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            data: DataConfig::Synth { seed: 11, count: 1500 },
            boundary,
            runs: 3,
            eval_every: 100,
            ..ExperimentConfig::paper_default()
        }
    }

    #[test]
    fn experiment_aggregates_runs() {
        let cfg = quick_cfg("t", AnyBoundary::Constant { delta: 0.1, paper_literal: false });
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.runs.len(), 3);
        assert!(out.avg_features > 0.0);
        assert!(!out.mean_features.is_empty());
        assert!(out.speedup(784) > 1.0, "attentive must save vs 784 dims");
    }

    #[test]
    fn sweep_preserves_order_and_determinism() {
        let cfgs = vec![
            quick_cfg("a", AnyBoundary::Full),
            quick_cfg("b", AnyBoundary::Constant { delta: 0.1, paper_literal: false }),
        ];
        let out1 = run_sweep(&cfgs).unwrap();
        let out2 = run_sweep(&cfgs).unwrap();
        assert_eq!(out1[0].name, "a");
        assert_eq!(out1[1].name, "b");
        // Determinism across invocations (thread-schedule independent).
        assert_eq!(out1[1].avg_features, out2[1].avg_features);
        assert_eq!(out1[0].final_test_error, out2[0].final_test_error);
        // Full computes everything; attentive strictly less.
        assert!(out1[1].avg_features < out1[0].avg_features);
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = quick_cfg("x", AnyBoundary::Full);
        cfg.lambda = -1.0;
        assert!(run_experiment(&cfg).is_err());
    }
}
