//! L3 coordinator: the training/serving control plane.
//!
//! Everything on the request path is rust: the online training loop
//! ([`trainer`]), the learner factory that materializes a configured
//! experiment ([`factory`]), the multi-run/multi-task parallel scheduler
//! that reproduces the paper's 10-permutation averages ([`scheduler`]),
//! an async prediction service with attentive early-exit ([`service`]),
//! and the wire-fed online trainers behind the `learn` op ([`online`]).

pub mod factory;
pub mod online;
pub mod scheduler;
pub mod service;
pub mod trainer;

pub use online::{LearnError, OnlineTrainer, TrainerStats, TrainerStatsSnapshot};
pub use scheduler::{run_sweep, SweepOutcome};
pub use trainer::{TrainReport, Trainer, TrainerConfig};
