//! Learner factory: [`ExperimentConfig`] → boxed [`OnlineLearner`].
//!
//! The CLI and the sweep scheduler construct learners from config files;
//! this is the single place where the (learner family × boundary family)
//! matrix is materialized.

use crate::config::{DataConfig, ExperimentConfig, LearnerKind, TrainerWireConfig};
use crate::stst::boundary::AnyBoundary;
use crate::data::dataset::Dataset;
use crate::data::synth::SynthDigits;
use crate::data::task::BinaryTask;
use crate::error::{Error, Result};
use crate::learner::passive_aggressive::BoundedPa;
use crate::learner::pegasos::{BoundedPegasos, PegasosConfig};
use crate::learner::perceptron::BoundedPerceptron;
use crate::learner::OnlineLearner;

/// Build the learner described by `cfg` (dimensionality from the task).
/// `run` perturbs the seed so repeated runs differ like the paper's 10
/// permutations.
pub fn build_learner(cfg: &ExperimentConfig, dim: usize, run: u64) -> Box<dyn OnlineLearner> {
    let pcfg = PegasosConfig {
        lambda: cfg.lambda,
        theta: cfg.theta,
        project: true,
        policy: cfg.policy,
        seed: cfg.seed ^ run.wrapping_mul(0xA076_1D64_78BD_642F),
        observe_on_full: true,
    };
    let boundary = cfg.boundary.clone();
    match cfg.learner {
        LearnerKind::Pegasos => Box::new(BoundedPegasos::new(dim, pcfg, boundary)),
        LearnerKind::Perceptron => Box::new(BoundedPerceptron::new(dim, pcfg, boundary)),
        LearnerKind::PassiveAggressive => {
            // PA's aggressiveness: C = 1/λ keeps the two families'
            // regularization knobs aligned.
            Box::new(BoundedPa::new(dim, pcfg, 1.0 / cfg.lambda, boundary))
        }
    }
}

/// Build the concrete attentive Pegasos behind a wire trainer
/// ([`crate::coordinator::online`]). Concrete (not boxed) because
/// snapshot publishing needs the learner's variance cache; `validate()`
/// on [`TrainerWireConfig`] guarantees `learner == Pegasos`.
pub fn build_wire_pegasos(cfg: &TrainerWireConfig, dim: usize) -> BoundedPegasos<AnyBoundary> {
    let pcfg = PegasosConfig {
        lambda: cfg.lambda,
        theta: 1.0,
        project: true,
        policy: cfg.policy,
        seed: cfg.seed,
        observe_on_full: true,
    };
    BoundedPegasos::new(dim, pcfg, cfg.boundary.clone())
}

/// Materialize the dataset described by `cfg.data`.
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    match &cfg.data {
        DataConfig::Synth { seed, count } => Ok(SynthDigits::new(*seed).generate(*count)),
        DataConfig::Mnist { dir, fallback_synth } => {
            match crate::data::mnist::load_mnist_dir(dir)? {
                Some(ds) => Ok(ds),
                None if *fallback_synth => {
                    eprintln!(
                        "warning: MNIST not found in {}, using synthetic digits",
                        dir.display()
                    );
                    Ok(SynthDigits::new(cfg.seed).generate(10_000))
                }
                None => Err(Error::Config(format!(
                    "MNIST files not found in {} (set fallback_synth to allow synthetic)",
                    dir.display()
                ))),
            }
        }
        DataConfig::Libsvm { path, dim } => crate::data::libsvm::read_file(path, *dim),
    }
}

/// Dataset → shuffled → 1-vs-1 task → (train, test) split.
pub fn build_task(cfg: &ExperimentConfig) -> Result<(BinaryTask, BinaryTask)> {
    let ds = build_dataset(cfg)?;
    let task = BinaryTask::one_vs_one(&ds, cfg.pair.0, cfg.pair.1)?;
    // Deterministic shuffle before the split so train/test are unbiased.
    let order = crate::data::stream::ShuffledIndices::new(task.len(), cfg.seed).epoch(0);
    let task = task.reindex(&order);
    Ok(task.split(cfg.train_fraction))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stst::boundary::AnyBoundary;

    #[test]
    fn factory_builds_all_learner_kinds() {
        let mut cfg = ExperimentConfig::paper_default();
        for kind in [LearnerKind::Pegasos, LearnerKind::Perceptron, LearnerKind::PassiveAggressive]
        {
            cfg.learner = kind;
            let l = build_learner(&cfg, 16, 0);
            assert_eq!(l.dim(), 16);
            assert!(!l.name().is_empty());
        }
    }

    #[test]
    fn factory_builds_all_boundaries() {
        let mut cfg = ExperimentConfig::paper_default();
        for b in [
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            AnyBoundary::Curved { delta: 0.1 },
            AnyBoundary::Budgeted { k: 10 },
            AnyBoundary::Full,
        ] {
            cfg.boundary = b;
            let mut l = build_learner(&cfg, 8, 1);
            let info = l.process(&[0.5; 8], 1.0);
            assert!(info.evaluated <= 8);
        }
    }

    #[test]
    fn task_split_respects_fraction() {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.data = DataConfig::Synth { seed: 3, count: 500 };
        let (train, test) = build_task(&cfg).unwrap();
        // 500 examples cycle 10 digits -> 50 of class 2 and 50 of class 3.
        assert_eq!(train.len() + test.len(), 100);
        assert_eq!(train.len(), 80);
    }

    #[test]
    fn run_seed_changes_learner_stream() {
        let cfg = ExperimentConfig::paper_default();
        let mut a = build_learner(&cfg, 32, 0);
        let mut b = build_learner(&cfg, 32, 1);
        // Same inputs, different policy RNG stream -> (almost surely)
        // different evaluation counts on a stochastic policy.
        let x: Vec<f64> = (0..32).map(|i| (i as f64 / 32.0) - 0.4).collect();
        let mut diff = false;
        for _ in 0..20 {
            if a.process(&x, 1.0).evaluated != b.process(&x, 1.0).evaluated {
                diff = true;
                break;
            }
        }
        assert!(diff, "different run seeds should perturb the stochastic policy");
    }

    #[test]
    fn mnist_source_requires_files_or_fallback() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let mut cfg = ExperimentConfig::paper_default();
        cfg.data =
            DataConfig::Mnist { dir: dir.path().to_path_buf(), fallback_synth: false };
        assert!(build_dataset(&cfg).is_err());
        cfg.data = DataConfig::Mnist { dir: dir.path().to_path_buf(), fallback_synth: true };
        assert!(build_dataset(&cfg).is_ok());
    }
}
