//! The online training loop.
//!
//! Streams a [`BinaryTask`] into an [`OnlineLearner`], collecting exactly
//! the series the paper's figures plot: cumulative average features per
//! example, held-out (generalization) error at checkpoints, and — in
//! audit mode — the true decision-error rate obtained by finishing every
//! stopped evaluation out-of-band.


use crate::data::stream::ShuffledIndices;
use crate::data::task::BinaryTask;
use crate::learner::OnlineLearner;
use crate::metrics::curve::{Checkpointer, Curve};
use crate::metrics::TrainingMetrics;
use crate::stst::decision::EvalOutcome;

/// Trainer knobs (orthogonal to learner hyper-parameters).
#[derive(Debug, Clone, Copy)]
pub struct TrainerConfig {
    /// Passes over the training set.
    pub epochs: u64,
    /// Evaluate held-out error every this many examples (0 = never).
    pub eval_every: u64,
    /// Shuffle seed for the stream order.
    pub seed: u64,
    /// Finish stopped evaluations out-of-band to measure the true
    /// decision-error rate (costs an extra full margin per early stop —
    /// measurement only, never affects learning).
    pub audit: bool,
    /// Record learning curves (off for pure benchmarking).
    pub curves: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self { epochs: 1, eval_every: 200, seed: 0, audit: false, curves: true }
    }
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Hot-path counters.
    pub metrics: TrainingMetrics,
    /// `(examples, cumulative avg features/example)`.
    pub features_curve: Curve,
    /// `(examples, held-out error)` — the generalization curve.
    pub test_error_curve: Curve,
    /// Final held-out error with full-computation prediction.
    pub final_test_error: f64,
    /// Final held-out error with the learner's early-stopped prediction.
    pub final_test_error_early: f64,
    /// Average features per example spent by early-stopped prediction on
    /// the held-out set.
    pub predict_avg_features: f64,
    /// Learner identity string.
    pub learner: String,
    /// Wall-clock seconds spent in the training loop (hot path only).
    pub train_seconds: f64,
}

impl TrainReport {
    /// Average features evaluated per training example.
    pub fn avg_features_per_example(&self) -> f64 {
        self.metrics.avg_features()
    }
}

/// Online trainer. Owns no model state; drives a learner over a task.
#[derive(Debug, Clone, Default)]
pub struct Trainer {
    cfg: TrainerConfig,
}

impl Trainer {
    /// Trainer with the given knobs.
    pub fn new(cfg: TrainerConfig) -> Self {
        Self { cfg }
    }

    /// Train on `task` with no held-out set.
    pub fn fit<L: OnlineLearner + ?Sized>(&self, learner: &mut L, task: &BinaryTask) -> TrainReport {
        self.fit_eval(learner, task, None)
    }

    /// Train on `train`, evaluating on `test` at checkpoints when given.
    pub fn fit_eval<L: OnlineLearner + ?Sized>(
        &self,
        learner: &mut L,
        train: &BinaryTask,
        test: Option<&BinaryTask>,
    ) -> TrainReport {
        let mut report = TrainReport {
            learner: learner.name(),
            features_curve: Curve::new(format!("{}/features", learner.name())),
            test_error_curve: Curve::new(format!("{}/test-error", learner.name())),
            ..Default::default()
        };
        let shuffler = ShuffledIndices::new(train.len(), self.cfg.seed);
        let ckpt = Checkpointer::new(self.cfg.eval_every.max(1));
        let t0 = std::time::Instant::now();

        for epoch in 0..self.cfg.epochs {
            for i in shuffler.epoch(epoch) {
                let (ex, y) = train.get(i);
                let info = learner.process(ex.features, y);

                if self.cfg.audit {
                    // Out-of-band: the true full margin decides whether an
                    // early stop was an error. Uses the *post-step* weights
                    // for non-updated examples, which is exact for skips.
                    // NOTE: ⟨w,x⟩ equals the walk's full sum only for
                    // permutation policies (sequential/sorted/permuted);
                    // with-replacement sampling draws a different S_n, so
                    // audit those runs with a permutation policy.
                    let full = learner.full_margin(ex.features);
                    let important = y * full < 1.0;
                    let o = match (info.early_stopped, important) {
                        (true, true) => EvalOutcome::StoppedBelow,
                        (true, false) => EvalOutcome::StoppedAbove,
                        (false, true) => EvalOutcome::FullBelow,
                        (false, false) => EvalOutcome::FullAbove,
                    };
                    report.metrics.audit.record(o);
                }

                report.metrics.record_example(
                    train.dim(),
                    info.evaluated,
                    info.updated,
                    info.early_stopped,
                    info.mistake,
                );

                if self.cfg.curves && ckpt.due(report.metrics.examples) {
                    report
                        .features_curve
                        .push(report.metrics.examples as f64, report.metrics.avg_features());
                    if let Some(test) = test {
                        if self.cfg.eval_every > 0 {
                            report.test_error_curve.push(
                                report.metrics.examples as f64,
                                Self::full_error(learner, test),
                            );
                        }
                    }
                }
            }
        }
        report.train_seconds = t0.elapsed().as_secs_f64();

        if let Some(test) = test {
            report.final_test_error = Self::full_error(learner, test);
            let (err_early, avg_feats) = Self::early_error(learner, test);
            report.final_test_error_early = err_early;
            report.predict_avg_features = avg_feats;
        }
        report
    }

    /// Held-out error with full margins.
    pub fn full_error<L: OnlineLearner + ?Sized>(learner: &L, test: &BinaryTask) -> f64 {
        if test.is_empty() {
            return 0.0;
        }
        let mut errs = 0usize;
        for i in 0..test.len() {
            let (ex, y) = test.get(i);
            if y * learner.full_margin(ex.features) <= 0.0 {
                errs += 1;
            }
        }
        errs as f64 / test.len() as f64
    }

    /// Held-out error with the learner's early-stopped prediction;
    /// returns `(error, avg features per prediction)`.
    pub fn early_error<L: OnlineLearner + ?Sized>(learner: &mut L, test: &BinaryTask) -> (f64, f64) {
        if test.is_empty() {
            return (0.0, 0.0);
        }
        let mut errs = 0usize;
        let mut feats = 0usize;
        for i in 0..test.len() {
            let (ex, y) = test.get(i);
            let (score, k) = learner.predict_early(ex.features);
            feats += k;
            if y * score <= 0.0 {
                errs += 1;
            }
        }
        (errs as f64 / test.len() as f64, feats as f64 / test.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthDigits;
    use crate::learner::pegasos::{BoundedPegasos, Pegasos, PegasosConfig};
    use crate::margin::policy::CoordinatePolicy;

    fn task_2v3(n: usize, seed: u64) -> (BinaryTask, BinaryTask) {
        let ds = SynthDigits::new(seed).generate_classes(n, &[2, 3]);
        let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
        task.split(0.8)
    }

    #[test]
    fn full_pegasos_learns_digits() {
        let (train, test) = task_2v3(800, 21);
        let mut l = Pegasos::full(train.dim(), PegasosConfig { lambda: 1e-2, ..Default::default() });
        let report = Trainer::new(TrainerConfig { eval_every: 0, ..Default::default() })
            .fit_eval(&mut l, &train, Some(&test));
        assert!(
            report.final_test_error < 0.1,
            "full Pegasos test error {} too high",
            report.final_test_error
        );
        assert_eq!(report.metrics.examples, 640);
        assert!((report.avg_features_per_example() - 784.0).abs() < 1e-9);
    }

    #[test]
    fn attentive_matches_accuracy_with_fewer_features() {
        // The paper's protocol averages runs over permutations — single
        // attentive runs have genuine variance (δ=0.1 tolerates decision
        // errors), so this asserts on a 3-run mean.
        let (train, test) = task_2v3(1000, 5);
        let mut err_full = 0.0;
        let mut err_att = 0.0;
        let mut feats_full = 0.0;
        let mut feats_att = 0.0;
        let runs = 3;
        for run in 0..runs {
            let trainer = Trainer::new(TrainerConfig {
                eval_every: 0,
                curves: false,
                epochs: 2,
                seed: run,
                ..Default::default()
            });
            // Permuted policy: permutation semantics make the sampled
            // partial sum an unbiased prefix of the true margin (the
            // weight-sampled policy's with-replacement estimator is
            // reweighted — see DESIGN.md §4 note — and has higher
            // run-to-run variance).
            let pcfg = PegasosConfig {
                lambda: 1e-2,
                seed: run,
                policy: CoordinatePolicy::Permuted,
                ..Default::default()
            };
            let mut full = Pegasos::full(train.dim(), pcfg);
            let rf = trainer.fit_eval(&mut full, &train, Some(&test));
            let mut att = BoundedPegasos::new(
                train.dim(),
                pcfg,
                crate::stst::boundary::ConstantBoundary::new(0.1),
            );
            let ra = trainer.fit_eval(&mut att, &train, Some(&test));
            err_full += rf.final_test_error / runs as f64;
            err_att += ra.final_test_error / runs as f64;
            feats_full += rf.avg_features_per_example() / runs as f64;
            feats_att += ra.avg_features_per_example() / runs as f64;
        }
        assert!(
            feats_att < feats_full / 2.0,
            "attentive features {feats_att:.1} vs full {feats_full:.1}"
        );
        assert!(
            err_att <= err_full + 0.05,
            "attentive mean err {err_att} vs full mean err {err_full}"
        );
    }

    #[test]
    fn audit_respects_delta_loosely() {
        let (train, _) = task_2v3(800, 9);
        let mut att = BoundedPegasos::new(
            train.dim(),
            PegasosConfig {
                lambda: 1e-2,
                policy: CoordinatePolicy::Permuted,
                ..Default::default()
            },
            crate::stst::boundary::ConstantBoundary::new(0.1),
        );
        let report = Trainer::new(TrainerConfig { audit: true, eval_every: 0, curves: false, ..Default::default() })
            .fit(&mut att, &train);
        let audit = &report.metrics.audit;
        assert!(audit.stopped() > 50, "too few early stops: {}", audit.stopped());
        // The theory bounds the conditional rate P(stop | S_n < θ) by δ,
        // but late in training "important" examples are rare, making that
        // conditional extremely noisy in a unit test (the rigorous check
        // is the Figure 2a simulator). Assert the robust curtailed rate:
        // erroneous stops as a fraction of all stops must be small.
        assert!(
            audit.curtailed_error_rate() < 0.2,
            "curtailed error rate {} too high ({} errors / {} stops)",
            audit.curtailed_error_rate(),
            audit.errors(),
            audit.stopped()
        );
    }

    #[test]
    fn curves_recorded_at_checkpoints() {
        let (train, test) = task_2v3(600, 2);
        let mut l = Pegasos::full(
            train.dim(),
            PegasosConfig { lambda: 1e-2, policy: CoordinatePolicy::Sequential, ..Default::default() },
        );
        let report = Trainer::new(TrainerConfig { eval_every: 100, ..Default::default() })
            .fit_eval(&mut l, &train, Some(&test));
        assert!(!report.features_curve.is_empty());
        assert_eq!(report.features_curve.len(), report.test_error_curve.len());
        // x positions are multiples of 100
        assert!(report.features_curve.xs.iter().all(|x| (x % 100.0) == 0.0));
    }

    #[test]
    fn epochs_multiply_examples() {
        let (train, _) = task_2v3(100, 3);
        let mut l = Pegasos::full(train.dim(), PegasosConfig::default());
        let report = Trainer::new(TrainerConfig { epochs: 3, eval_every: 0, curves: false, ..Default::default() })
            .fit(&mut l, &train);
        assert_eq!(report.metrics.examples, 3 * train.len() as u64);
    }
}
