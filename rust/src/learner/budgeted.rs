//! Budgeted Pegasos — the fixed-feature-budget baseline (green curves).
//!
//! The paper's comparison protocol (§4.1): first run Attentive Pegasos,
//! measure its average feature count, then give Budgeted Pegasos exactly
//! that many features for *every* example ("the budgeted learning
//! approach would evaluate the same number of features for both
//! examples", Figure 1). Note sorting is excluded for the budgeted
//! baseline — "sorting under the Budgeted Pegasos is impossible since we
//! need to learn the weights in order to sort them."

use crate::learner::pegasos::{BoundedPegasos, PegasosConfig};
use crate::margin::policy::CoordinatePolicy;
use crate::stst::boundary::BudgetedBoundary;

/// Budgeted Pegasos: Pegasos + fixed per-example feature budget.
pub type BudgetedPegasos = BoundedPegasos<BudgetedBoundary>;

/// Build a budgeted learner with budget `k`. Panics if a weight-sorted
/// policy is requested — that pairing is impossible per the paper.
pub fn budgeted_pegasos(
    dim: usize,
    lambda: f64,
    k: usize,
    policy: CoordinatePolicy,
    seed: u64,
) -> BudgetedPegasos {
    assert!(
        policy != CoordinatePolicy::SortedByWeight,
        "budgeted + sorted is impossible (paper §4.1): sorting needs learned weights"
    );
    BoundedPegasos::new(
        dim,
        PegasosConfig { lambda, policy, seed, ..Default::default() },
        BudgetedBoundary::new(k),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::OnlineLearner;

    #[test]
    fn budget_is_respected_every_example() {
        let dim = 100;
        let mut l = budgeted_pegasos(dim, 0.01, 9, CoordinatePolicy::Permuted, 3);
        for i in 0..50 {
            let y = if i % 2 == 0 { 1.0 } else { -1.0 };
            let x: Vec<f64> = (0..dim).map(|j| ((i + j) % 5) as f64 / 5.0 * y).collect();
            let info = l.process(&x, y);
            assert_eq!(info.evaluated, 9, "budgeted must spend exactly k features");
            assert!(!info.early_stopped);
        }
    }

    #[test]
    #[should_panic(expected = "impossible")]
    fn sorted_policy_rejected() {
        budgeted_pegasos(10, 0.01, 5, CoordinatePolicy::SortedByWeight, 0);
    }

    #[test]
    fn budget_larger_than_dim_truncates() {
        let mut l = budgeted_pegasos(4, 0.01, 100, CoordinatePolicy::Sequential, 0);
        let info = l.process(&[1.0, 1.0, 1.0, 1.0], 1.0);
        assert_eq!(info.evaluated, 4);
    }
}
