//! Early-stopped prediction (paper §4.1, right subfigures).
//!
//! At prediction time the threshold of interest is θ = 0 (sign of the
//! margin), and the test is **two-sided**: stop as soon as the partial
//! margin's magnitude clears the Constant STST level
//! `τ = sqrt(var(S_n)·log(1/√δ))` (Theorem 1's simplified form — the
//! paper notes the θ=0 boundary makes the decision error a *classification*
//! error, "a fact clearly evident throughout the experiments").

use crate::margin::policy::OrderGenerator;
use crate::stst::boundary::{Boundary, BoundaryTable, StopContext};

/// Two-sided sequential sign predictor under a stopping boundary.
#[derive(Debug, Clone, Copy)]
pub struct EarlyStopPredictor<'b, B: Boundary + ?Sized> {
    boundary: &'b B,
}

impl<'b, B: Boundary + ?Sized> EarlyStopPredictor<'b, B> {
    /// Predictor driven by `boundary`.
    pub fn new(boundary: &'b B) -> Self {
        Self { boundary }
    }

    /// Sequentially evaluate `⟨w, x⟩` in `order`, stopping when
    /// `|S_i| ≥ τ_i` (θ = 0). Returns `(score, features_evaluated)`;
    /// `score`'s sign is the prediction.
    pub fn predict(&self, w: &[f64], x: &[f64], order: &[usize], var_sn: f64) -> (f64, usize) {
        let n = order.len();
        let mut ctx = StopContext { evaluated: 0, total: n, theta: 0.0, var_sn };
        let cap = self.boundary.budget(&ctx).unwrap_or(n).min(n);
        let mut s = 0.0;
        if !self.boundary.is_evidence_based() {
            for &j in &order[..cap] {
                s += w[j] * x[j];
            }
            return (s, cap);
        }
        for (i, &j) in order[..cap].iter().enumerate() {
            s += w[j] * x[j];
            ctx.evaluated = i + 1;
            if ctx.evaluated < n {
                let tau = self.boundary.level(&ctx);
                // Strict: a zero-variance (untrained) model must not
                // claim confidence at |S| = τ = 0.
                if s.abs() > tau {
                    return (s, ctx.evaluated);
                }
            }
        }
        (s, cap)
    }

    /// Sparse variant of [`Self::predict`]: the example is given as
    /// `(idx, val)` pairs and `order` holds **positions into `idx`**
    /// (e.g. from [`OrderGenerator::next_sparse`]). Zero coordinates
    /// contribute nothing to `⟨w, x⟩`, so walking only the support is
    /// lossless; the stopping context's `total` is the support size —
    /// per-example cost is O(evaluated) ≤ O(nnz), never O(dim).
    pub fn predict_sparse(
        &self,
        w: &[f64],
        idx: &[u32],
        val: &[f64],
        order: &[usize],
        var_sn: f64,
    ) -> (f64, usize) {
        let n = order.len();
        let mut ctx = StopContext { evaluated: 0, total: n, theta: 0.0, var_sn };
        let cap = self.boundary.budget(&ctx).unwrap_or(n).min(n);
        let mut s = 0.0;
        if !self.boundary.is_evidence_based() {
            for &p in &order[..cap] {
                s += w[idx[p] as usize] * val[p];
            }
            return (s, cap);
        }
        for (i, &p) in order[..cap].iter().enumerate() {
            s += w[idx[p] as usize] * val[p];
            ctx.evaluated = i + 1;
            if ctx.evaluated < n {
                let tau = self.boundary.level(&ctx);
                if s.abs() > tau {
                    return (s, ctx.evaluated);
                }
            }
        }
        (s, cap)
    }

    /// Lazy-order variant of [`Self::predict`]: draws coordinates from
    /// the policy generator on demand (O(evaluated) policy cost).
    pub fn predict_lazy(
        &self,
        w: &[f64],
        x: &[f64],
        orders: &mut OrderGenerator,
        var_sn: f64,
    ) -> (f64, usize) {
        let n = w.len();
        orders.begin_example();
        let mut ctx = StopContext { evaluated: 0, total: n, theta: 0.0, var_sn };
        let cap = self.boundary.budget(&ctx).unwrap_or(n).min(n);
        let mut s = 0.0;
        if !self.boundary.is_evidence_based() {
            for _ in 0..cap {
                let j = orders.next_coord();
                s += w[j] * x[j];
            }
            return (s, cap);
        }
        for i in 0..cap {
            let j = orders.next_coord();
            s += w[j] * x[j];
            ctx.evaluated = i + 1;
            if ctx.evaluated < n {
                let tau = self.boundary.level(&ctx);
                if s.abs() > tau {
                    return (s, ctx.evaluated);
                }
            }
        }
        (s, cap)
    }
}

/// Number of terms gathered per block by [`TabledPredictor`]. Small enough
/// that a wasted partial block on an early stop is cheap, large enough for
/// the multiply stage to vectorize.
const BLOCK: usize = 16;

/// Blocked, LUT-driven variant of [`EarlyStopPredictor`] for the serving
/// hot path.
///
/// Two restructurings over the scalar walker, both bit-identical in output:
///
/// * Thresholds come from a precomputed [`BoundaryTable`] instead of the
///   `sqrt`-laden closed form — the table stores the *exact* values
///   [`Boundary::level`] would return (see `stst::boundary`), and for the
///   common flat (Constant STST) case the single τ is hoisted out of the
///   loop entirely.
/// * Terms are gathered block-at-a-time into a fixed-size buffer (a tight,
///   auto-vectorizable multiply loop over `[f64; BLOCK]`), then folded into
///   the running sum **sequentially, one accumulator, in walk order** — so
///   floating-point association is unchanged and every partial sum `S_i`
///   matches the scalar walk bit for bit. Stops still fire per feature;
///   for non-evidence boundaries (budgeted/full) no stop can ever fire, so
///   those walks run check-free over `chunks_exact` blocks.
///
/// The `(score, features_evaluated)` pair is guaranteed equal — as in
/// `assert_eq!`, not approximately — to [`EarlyStopPredictor`] driven by
/// the boundary the table was built from.
///
/// Under overload brownout the serving layer swaps in a table built by
/// `BoundaryTable::for_boundary_scaled` with `tighten < 1`: every stop
/// level shrinks multiplicatively, so a tightened walk stops **no
/// later** than the plain one on the same example (the partial sums are
/// identical up to the earlier stop; only the exit step can move, and
/// only downward). `tighten = 1` is the plain table, bit-identical —
/// tier 0 costs nothing.
#[derive(Debug, Clone, Copy)]
pub struct TabledPredictor<'t> {
    table: &'t BoundaryTable,
}

impl<'t> TabledPredictor<'t> {
    /// Predictor driven by a precomputed threshold table.
    pub fn new(table: &'t BoundaryTable) -> Self {
        Self { table }
    }

    /// Blocked walk shared by the dense and sparse entry points: `term(j)`
    /// produces the j-th term, where `j` ranges over `order`'s values.
    fn walk(&self, order: &[usize], term: impl Fn(usize) -> f64) -> (f64, usize) {
        let n = order.len();
        let mut buf = [0.0f64; BLOCK];
        if !self.table.is_evidence_based() {
            // No stop can fire: pure blocked accumulation up to the cap.
            let cap = self.table.cap(n);
            let mut s = 0.0;
            let mut chunks = order[..cap].chunks_exact(BLOCK);
            for chunk in chunks.by_ref() {
                // Fixed-size gather-multiply: the vectorizable stage.
                for (slot, &j) in buf.iter_mut().zip(chunk) {
                    *slot = term(j);
                }
                // Single-accumulator fold in walk order: same FP
                // association as the scalar loop.
                for &t in &buf {
                    s += t;
                }
            }
            for &j in chunks.remainder() {
                s += term(j);
            }
            return (s, cap);
        }
        debug_assert!(
            self.table.supports_total(n),
            "boundary table built for a different walk length"
        );
        let flat = self.table.flat_level();
        let mut s = 0.0;
        let mut evaluated = 0usize;
        for chunk in order.chunks(BLOCK) {
            for (slot, &j) in buf.iter_mut().zip(chunk) {
                *slot = term(j);
            }
            for &t in &buf[..chunk.len()] {
                s += t;
                evaluated += 1;
                // Strict compare, and never at the endpoint — identical
                // to the scalar walker's stop rule.
                if evaluated < n {
                    let tau = match flat {
                        Some(tau) => tau,
                        None => self.table.level_at(evaluated),
                    };
                    if s.abs() > tau {
                        return (s, evaluated);
                    }
                }
            }
        }
        (s, evaluated)
    }

    /// Blocked equivalent of [`EarlyStopPredictor::predict`] (`var_sn` is
    /// baked into the table).
    pub fn predict(&self, w: &[f64], x: &[f64], order: &[usize]) -> (f64, usize) {
        self.walk(order, |j| w[j] * x[j])
    }

    /// Blocked equivalent of [`EarlyStopPredictor::predict_sparse`]:
    /// `order` holds positions into `idx`/`val`.
    pub fn predict_sparse(
        &self,
        w: &[f64],
        idx: &[u32],
        val: &[f64],
        order: &[usize],
    ) -> (f64, usize) {
        self.walk(order, |p| w[idx[p] as usize] * val[p])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stst::boundary::{
        AnyBoundary, BudgetedBoundary, ConstantBoundary, TrivialBoundary,
    };

    #[test]
    fn full_boundary_full_evaluation() {
        let w = [1.0, -2.0, 3.0];
        let x = [0.5, 0.5, 0.5];
        let order = [0usize, 1, 2];
        let p = EarlyStopPredictor::new(&TrivialBoundary);
        let (score, k) = p.predict(&w, &x, &order, 1.0);
        assert_eq!(k, 3);
        assert!((score - 1.0).abs() < 1e-12);
    }

    #[test]
    fn confident_example_stops_early_either_sign() {
        let n = 200;
        let order: Vec<usize> = (0..n).collect();
        let b = ConstantBoundary::new(0.1);
        let p = EarlyStopPredictor::new(&b);
        let w = vec![1.0; n];
        let x_pos = vec![1.0; n];
        let (s_pos, k_pos) = p.predict(&w, &x_pos, &order, 4.0);
        assert!(s_pos > 0.0);
        assert!(k_pos < n / 4, "positive example should stop early, took {k_pos}");
        let x_neg = vec![-1.0; n];
        let (s_neg, k_neg) = p.predict(&w, &x_neg, &order, 4.0);
        assert!(s_neg < 0.0);
        assert_eq!(k_neg, k_pos, "symmetric example stops symmetrically");
    }

    #[test]
    fn budgeted_prediction_truncates() {
        let n = 50;
        let order: Vec<usize> = (0..n).collect();
        let b = BudgetedBoundary::new(5);
        let p = EarlyStopPredictor::new(&b);
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let (s, k) = p.predict(&w, &x, &order, 1.0);
        assert_eq!(k, 5);
        assert!((s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_walk_matches_dense_on_the_support() {
        // Same boundary, same visiting sequence: the dense walk ordered
        // support-first must agree with the sparse walk exactly.
        let n = 32;
        let w: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let mut x = vec![0.0; n];
        let idx: Vec<u32> = vec![4, 9, 20, 31];
        let val = vec![0.8, -0.3, 1.1, 0.6];
        for (&i, &v) in idx.iter().zip(&val) {
            x[i as usize] = v;
        }
        let b = ConstantBoundary::new(0.1);
        let p = EarlyStopPredictor::new(&b);
        let sparse_order: Vec<usize> = (0..idx.len()).collect();
        let (s_sparse, k_sparse) = p.predict_sparse(&w, &idx, &val, &sparse_order, 4.0);
        // Dense walk visiting the support coordinates first, zeros after.
        let mut dense_order: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
        dense_order.extend((0..n).filter(|j| !idx.contains(&(*j as u32))));
        let (s_dense, k_dense) = p.predict(&w, &x, &dense_order, 4.0);
        if k_sparse < idx.len() {
            // Early exit happened inside the support: identical walks.
            assert_eq!(k_dense, k_sparse);
            assert!((s_dense - s_sparse).abs() < 1e-12);
        } else {
            // Sparse capped at nnz; the dense walk's extra zero terms
            // cannot change the sum.
            assert!((s_dense - s_sparse).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_budgeted_caps_at_support() {
        let idx: Vec<u32> = vec![1, 5, 9];
        let val = vec![1.0, 1.0, 1.0];
        let w = vec![1.0; 16];
        let order: Vec<usize> = (0..3).collect();
        let b = BudgetedBoundary::new(10);
        let p = EarlyStopPredictor::new(&b);
        let (s, k) = p.predict_sparse(&w, &idx, &val, &order, 1.0);
        assert_eq!(k, 3, "budget larger than the support caps at nnz");
        assert!((s - 3.0).abs() < 1e-12);
        let (_, k2) = p.predict_sparse(&w, &idx, &val, &order[..0], 1.0);
        assert_eq!(k2, 0, "empty order evaluates nothing");
    }

    #[test]
    fn ambiguous_example_runs_to_completion() {
        let n = 64;
        let order: Vec<usize> = (0..n).collect();
        let b = ConstantBoundary::new(0.01);
        let p = EarlyStopPredictor::new(&b);
        let w = vec![1.0; n];
        // alternating: partial sums oscillate around 0
        let x: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 0.01 } else { -0.01 }).collect();
        let (_, k) = p.predict(&w, &x, &order, 10.0);
        assert_eq!(k, n, "oscillating margin must not stop early");
    }

    /// Deterministic pseudo-random f64 in [-1, 1] (xorshift; no deps).
    fn prng(state: &mut u64) -> f64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        (*state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
    }

    fn families() -> Vec<AnyBoundary> {
        vec![
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            AnyBoundary::Constant { delta: 0.01, paper_literal: true },
            AnyBoundary::Curved { delta: 0.05 },
            AnyBoundary::Budgeted { k: 9 },
            AnyBoundary::Full,
        ]
    }

    #[test]
    fn tabled_predictor_matches_scalar_bit_for_bit_dense() {
        // The blocked LUT kernel must return *exactly* the scalar
        // walker's (score, features_evaluated) — assert_eq! on the f64,
        // no tolerance — across families, walk lengths straddling the
        // block size, and variances spanning stop-early to never-stop.
        let mut seed = 0x5eed_1234_u64;
        for boundary in families() {
            for &n in &[1usize, 7, 16, 17, 48, 100, 200] {
                for &var_sn in &[0.05, 4.0, 1e4] {
                    let w: Vec<f64> = (0..n).map(|_| prng(&mut seed)).collect();
                    let x: Vec<f64> = (0..n).map(|_| prng(&mut seed)).collect();
                    let order: Vec<usize> = (0..n).rev().collect();
                    let table = BoundaryTable::for_boundary(&boundary, var_sn, n);
                    let scalar = EarlyStopPredictor::new(&boundary);
                    let tabled = TabledPredictor::new(&table);
                    assert_eq!(
                        tabled.predict(&w, &x, &order),
                        scalar.predict(&w, &x, &order, var_sn),
                        "{} n={n} var={var_sn}",
                        boundary.name()
                    );
                }
            }
        }
    }

    #[test]
    fn tabled_predictor_matches_scalar_bit_for_bit_sparse() {
        let mut seed = 0xfeed_5678_u64;
        for boundary in families() {
            for &nnz in &[1usize, 3, 16, 31, 64] {
                for &var_sn in &[0.05, 4.0, 1e4] {
                    let dim = nnz * 4;
                    let w: Vec<f64> = (0..dim).map(|_| prng(&mut seed)).collect();
                    let idx: Vec<u32> = (0..nnz).map(|i| (i * 4) as u32).collect();
                    let val: Vec<f64> = (0..nnz).map(|_| prng(&mut seed)).collect();
                    let order: Vec<usize> = (0..nnz).collect();
                    let table = BoundaryTable::for_boundary(&boundary, var_sn, nnz);
                    let scalar = EarlyStopPredictor::new(&boundary);
                    let tabled = TabledPredictor::new(&table);
                    assert_eq!(
                        tabled.predict_sparse(&w, &idx, &val, &order),
                        scalar.predict_sparse(&w, &idx, &val, &order, var_sn),
                        "{} nnz={nnz} var={var_sn}",
                        boundary.name()
                    );
                }
            }
        }
    }

    #[test]
    fn tightened_tables_stop_no_later_than_plain() {
        // The brownout guarantee: scaling every stop level down by
        // `tighten` can only move an exit earlier, never later, and the
        // two walks' partial sums agree up to the tightened exit. At
        // `tighten = 1.0` the scaled constructor is the plain one.
        let mut seed = 0xb07_0u64 + 13;
        for boundary in families() {
            for &n in &[7usize, 16, 48, 200] {
                for &var_sn in &[0.05, 4.0, 1e4] {
                    let w: Vec<f64> = (0..n).map(|_| prng(&mut seed)).collect();
                    let x: Vec<f64> = (0..n).map(|_| prng(&mut seed)).collect();
                    let order: Vec<usize> = (0..n).collect();
                    let plain = BoundaryTable::for_boundary(&boundary, var_sn, n);
                    let (s_plain, k_plain) = TabledPredictor::new(&plain).predict(&w, &x, &order);
                    for &tighten in &[0.5, 0.25, 0.0625] {
                        let tight =
                            BoundaryTable::for_boundary_scaled(&boundary, var_sn, n, tighten);
                        let (s_tight, k_tight) =
                            TabledPredictor::new(&tight).predict(&w, &x, &order);
                        assert!(
                            k_tight <= k_plain,
                            "{} n={n} var={var_sn} tighten={tighten}: \
                             tightened walk took {k_tight} > plain {k_plain}",
                            boundary.name()
                        );
                        if k_tight == k_plain {
                            // Same exit step ⇒ same partial sum, exactly.
                            assert_eq!(s_tight, s_plain, "{} n={n}", boundary.name());
                        }
                    }
                    let unit = BoundaryTable::for_boundary_scaled(&boundary, var_sn, n, 1.0);
                    assert_eq!(
                        TabledPredictor::new(&unit).predict(&w, &x, &order),
                        (s_plain, k_plain),
                        "tighten=1.0 must be the plain table, bit for bit"
                    );
                }
            }
        }
    }

    #[test]
    fn tabled_predictor_stops_early_mid_block() {
        // Sanity that the equivalence tests above actually exercise the
        // stop path: a confident example must exit inside a block, not
        // only at block edges, and at the same step as the scalar walk.
        let n = 200;
        let order: Vec<usize> = (0..n).collect();
        let boundary = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
        let table = BoundaryTable::for_boundary(&boundary, 4.0, n);
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let (s, k) = TabledPredictor::new(&table).predict(&w, &x, &order);
        let scalar = EarlyStopPredictor::new(&boundary);
        assert_eq!((s, k), scalar.predict(&w, &x, &order, 4.0));
        assert!(k < n / 4, "confident example should stop early, took {k}");
        assert!(k % super::BLOCK != 0, "pick a case that stops mid-block, stopped at {k}");
    }
}
