//! Pegasos with a pluggable stopping boundary — the paper's Algorithm 1
//! in its general form.
//!
//! [`BoundedPegasos<B>`] runs the Pegasos SGD/projection scheme
//! (Shalev-Shwartz, Singer, Srebro, Cotter 2010) but evaluates each
//! example's margin *sequentially* under boundary `B`:
//!
//! * `B = TrivialBoundary` → vanilla **Pegasos** (full computation, the
//!   red curves of Figures 3–4);
//! * `B = ConstantBoundary` → **Attentive Pegasos** (blue curves);
//! * `B = BudgetedBoundary` → **Budgeted Pegasos** (green curves).
//!
//! One online step (Algorithm 1):
//!
//! ```text
//! if ∃ i ≤ n :  y·Σ_{j≤i} w_j x_j ≥ θ + τ(δ, var̂(S_n))   →  skip
//!     (update var̂_y(x_j) for the evaluated prefix)
//! else (full margin y·⟨w,x⟩ known):
//!     if y·⟨w,x⟩ < θ:   μ ← 1/(λt);  w ← (1−μλ)w + μ y x;
//!                        w ← min(1, (1/√λ)/‖w‖)·w          (projection)
//! ```


use crate::margin::policy::{CoordinatePolicy, OrderGenerator};
use crate::margin::walker::{WalkOutcome, Walker};
use crate::stst::boundary::Boundary;

use super::predictor::EarlyStopPredictor;
use super::var_cache::VarCache;
use super::{OnlineLearner, StepInfo};

/// Hyper-parameters shared by all Pegasos variants.
#[derive(Debug, Clone, Copy)]
pub struct PegasosConfig {
    /// Regularization λ (> 0). Learning rate is `1/(λ t)`.
    pub lambda: f64,
    /// Margin decision threshold θ (1.0 = the hinge; the paper's
    /// "importance threshold").
    pub theta: f64,
    /// Apply the `‖w‖ ≤ 1/√λ` projection after each update.
    pub project: bool,
    /// Coordinate visit order.
    pub policy: CoordinatePolicy,
    /// Seed for the policy's RNG stream.
    pub seed: u64,
    /// Update the variance table on fully-evaluated examples too
    /// (Algorithm 1 as printed only updates it on skipped ones; `true`
    /// uses all evaluated coordinates — strictly more information,
    /// flag kept for the fidelity ablation).
    pub observe_on_full: bool,
}

impl Default for PegasosConfig {
    fn default() -> Self {
        Self {
            lambda: 1e-4,
            theta: 1.0,
            project: true,
            policy: CoordinatePolicy::WeightSampled,
            seed: 0,
            observe_on_full: true,
        }
    }
}

/// Pegasos with sequential margin evaluation under boundary `B`.
#[derive(Debug, Clone)]
pub struct BoundedPegasos<B: Boundary> {
    cfg: PegasosConfig,
    boundary: B,
    w: Vec<f64>,
    /// Update counter t (Pegasos learning-rate schedule).
    t: u64,
    vars: VarCache,
    orders: OrderGenerator,
    walker: Walker,
    /// ‖w‖² tracked incrementally for the O(1) projection decision.
    norm_sq: f64,
    orders_dirty: bool,
    /// scratch: coordinates visited by the last walk (variance update).
    visited: Vec<usize>,
}

impl<B: Boundary> BoundedPegasos<B> {
    /// Fresh learner at `w = 0` (norm 0 ≤ 1/√λ, satisfying Pegasos's
    /// initialization constraint).
    pub fn new(dim: usize, cfg: PegasosConfig, boundary: B) -> Self {
        assert!(cfg.lambda > 0.0, "lambda must be positive");
        Self {
            cfg,
            boundary,
            w: vec![0.0; dim],
            t: 0,
            vars: VarCache::new(dim),
            orders: OrderGenerator::new(cfg.policy, cfg.seed),
            walker: Walker::new(),
            norm_sq: 0.0,
            orders_dirty: true,
            visited: Vec::with_capacity(dim),
        }
    }

    /// The boundary driving the attention mechanism.
    pub fn boundary(&self) -> &B {
        &self.boundary
    }

    /// Hyper-parameters.
    pub fn config(&self) -> &PegasosConfig {
        &self.cfg
    }

    /// Number of updates performed so far.
    pub fn updates(&self) -> u64 {
        self.t
    }

    /// Variance table (exposed for the early-stop predictor and tests).
    pub fn var_cache_mut(&mut self) -> &mut VarCache {
        &mut self.vars
    }

    /// Resume from a published snapshot instead of `w = 0`: restore the
    /// weight vector (projected back onto the `‖w‖ ≤ 1/√λ` Pegasos ball
    /// if the restoring λ differs from the training one) and seed the
    /// variance table so the boundary trusts the snapshot's observed
    /// spread rather than restarting from the uninformed prior.
    ///
    /// A zero or malformed snapshot (all-zero weights, wrong length, a
    /// non-finite entry) leaves the learner exactly at cold start — so a
    /// trainer attached to a placeholder shard behaves bit-identically
    /// to a fresh one.
    ///
    /// The update clock matters: Pegasos's first step uses
    /// `decay = 1 − 1/t = 0`, which would erase restored weights. The
    /// clock therefore resumes at `t ≈ 1/λ`, the horizon where the
    /// per-step decay has the same magnitude as the regularizer — late
    /// enough that the snapshot survives its first violation, early
    /// enough that the model keeps adapting.
    pub fn warm_start(&mut self, weights: &[f64], var_sn: f64) {
        if weights.len() != self.w.len() || weights.iter().any(|w| !w.is_finite()) {
            return;
        }
        let mut norm_sq: f64 = weights.iter().map(|w| w * w).sum();
        if norm_sq == 0.0 {
            return;
        }
        self.w.copy_from_slice(weights);
        if self.cfg.project {
            let limit_sq = 1.0 / self.cfg.lambda;
            if norm_sq > limit_sq {
                let c = (limit_sq / norm_sq).sqrt();
                for wj in self.w.iter_mut() {
                    *wj *= c;
                }
                norm_sq = limit_sq;
            }
        }
        self.norm_sq = norm_sq;
        self.t = self.t.max((1.0 / self.cfg.lambda).round().max(1.0) as u64);
        // The snapshot's var_sn is Σ_j w_j²·var(x_j); dividing by Σ w_j²
        // recovers the average per-feature variance, the right prior for
        // every coordinate until live observations replace it.
        let prior = var_sn / norm_sq;
        let prior = if prior.is_finite() && prior >= 0.0 {
            prior
        } else {
            crate::stst::variance::ClassVariance::DEFAULT_PRIOR
        };
        self.vars = VarCache::with_prior(self.w.len(), prior);
        self.orders_dirty = true;
    }

    /// Perform the Pegasos gradient + projection step for a violating
    /// example. O(n) — allowed, updates only happen on violations.
    fn update(&mut self, x: &[f64], y: f64) {
        self.t += 1;
        let mu = 1.0 / (self.cfg.lambda * self.t as f64);
        let decay = 1.0 - mu * self.cfg.lambda; // = 1 - 1/t
        let mut norm_sq = 0.0;
        for (wj, &xj) in self.w.iter_mut().zip(x) {
            *wj = decay * *wj + mu * y * xj;
            norm_sq += *wj * *wj;
        }
        self.norm_sq = norm_sq;
        if self.cfg.project {
            let limit = 1.0 / self.cfg.lambda.sqrt();
            let norm = self.norm_sq.sqrt();
            if norm > limit {
                let c = limit / norm;
                for wj in self.w.iter_mut() {
                    *wj *= c;
                }
                self.norm_sq *= c * c;
            }
        }
        self.vars.invalidate();
        self.orders_dirty = true;
    }
}

impl<B: Boundary> OnlineLearner for BoundedPegasos<B> {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn process(&mut self, x: &[f64], y: f64) -> StepInfo {
        debug_assert_eq!(x.len(), self.w.len());
        if self.orders_dirty {
            self.orders.refresh(&self.w);
            self.orders_dirty = false;
        }
        let var_sn = self.vars.var_sn(y, &self.w);
        // Lazy draws: an early stop after k coordinates costs O(k), not
        // the O(n) a materialized order would (EXPERIMENTS.md §Perf).
        let mut visited = std::mem::take(&mut self.visited);
        let res = self.walker.walk_lazy(
            &self.w,
            x,
            y,
            &mut self.orders,
            self.cfg.theta,
            var_sn,
            &self.boundary,
            &mut visited,
        );

        let mistake = res.partial_margin <= 0.0;
        let info = match res.outcome {
            WalkOutcome::EarlyStopped => {
                // Algorithm 1: update variance over the evaluated prefix,
                // keep weights, jump to next example.
                self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                StepInfo {
                    evaluated: res.evaluated,
                    updated: false,
                    early_stopped: true,
                    margin: res.partial_margin,
                    mistake: false, // skipped examples are confidently correct
                    outcome: res.outcome,
                }
            }
            WalkOutcome::BudgetExhausted | WalkOutcome::Completed => {
                // Variance only feeds the STST level; evidence-free
                // boundaries (full/budgeted) never consult it — vanilla
                // Pegasos tracks no per-feature statistics (paper Alg. 1).
                if self.cfg.observe_on_full && self.boundary.is_evidence_based() {
                    self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                }
                let updated = res.partial_margin < self.cfg.theta;
                if updated {
                    self.update(x, y);
                }
                StepInfo {
                    evaluated: res.evaluated,
                    updated,
                    early_stopped: false,
                    margin: res.partial_margin,
                    mistake,
                    outcome: res.outcome,
                }
            }
        };
        self.visited = visited;
        info
    }

    fn predict_early(&mut self, x: &[f64]) -> (f64, usize) {
        use crate::stst::boundary::StopContext;
        let probe =
            StopContext { evaluated: 0, total: self.w.len(), theta: 0.0, var_sn: 0.0 };
        if !self.boundary.is_evidence_based() && self.boundary.budget(&probe).is_none() {
            // Trivial boundary: the exact dense margin (with-replacement
            // orders would otherwise give a sampled estimate).
            return (crate::margin::dot(&self.w, x), self.w.len());
        }
        if self.orders_dirty {
            self.orders.refresh(&self.w);
            self.orders_dirty = false;
        }
        let var_pos = self.vars.var_sn(1.0, &self.w);
        let var_neg = self.vars.var_sn(-1.0, &self.w);
        let predictor = EarlyStopPredictor::new(&self.boundary);
        predictor.predict_lazy(&self.w, x, &mut self.orders, var_pos.max(var_neg))
    }

    fn name(&self) -> String {
        format!("pegasos[{}/{}]", self.boundary.name(), self.cfg.policy.name())
    }
}

/// Vanilla full-computation Pegasos (trivial boundary).
pub type Pegasos = BoundedPegasos<crate::stst::boundary::TrivialBoundary>;

impl Pegasos {
    /// Vanilla Pegasos evaluating every feature of every example.
    pub fn full(dim: usize, cfg: PegasosConfig) -> Self {
        BoundedPegasos::new(dim, cfg, crate::stst::boundary::TrivialBoundary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stst::boundary::{ConstantBoundary, TrivialBoundary};

    fn separable_stream(n: usize, dim: usize) -> Vec<(Vec<f64>, f64)> {
        // y = sign of mean of first half minus second half; strongly
        // separable with margin.
        (0..n)
            .map(|i| {
                let y = if i % 2 == 0 { 1.0 } else { -1.0 };
                let x: Vec<f64> = (0..dim)
                    .map(|j| {
                        let base = if j < dim / 2 { y } else { -y };
                        base * (0.8 + 0.2 * ((i * 31 + j * 7) % 10) as f64 / 10.0)
                    })
                    .collect();
                (x, y)
            })
            .collect()
    }

    #[test]
    fn pegasos_learns_separable_data() {
        let dim = 20;
        let mut l = Pegasos::full(dim, PegasosConfig { lambda: 0.01, ..Default::default() });
        for (x, y) in separable_stream(500, dim) {
            l.process(&x, y);
        }
        // All examples classified correctly at the end.
        let mut errs = 0;
        for (x, y) in separable_stream(100, dim) {
            if y * l.full_margin(&x) <= 0.0 {
                errs += 1;
            }
        }
        assert_eq!(errs, 0, "vanilla Pegasos failed separable data");
        assert!(l.updates() > 0);
    }

    #[test]
    fn projection_keeps_norm_bounded() {
        let dim = 10;
        let lambda = 0.01;
        let mut l = Pegasos::full(dim, PegasosConfig { lambda, ..Default::default() });
        for (x, y) in separable_stream(300, dim) {
            l.process(&x, y);
            let norm = l.weights().iter().map(|w| w * w).sum::<f64>().sqrt();
            assert!(norm <= 1.0 / lambda.sqrt() + 1e-9, "norm {norm} exceeds Pegasos ball");
        }
    }

    #[test]
    fn attentive_spends_fewer_features_same_accuracy() {
        let dim = 64;
        let stream = separable_stream(1200, dim);
        let cfg = PegasosConfig { lambda: 0.01, policy: CoordinatePolicy::Sequential, ..Default::default() };
        let mut full = BoundedPegasos::new(dim, cfg, TrivialBoundary);
        let mut att = BoundedPegasos::new(dim, cfg, ConstantBoundary::new(0.1));
        let (mut f_feats, mut a_feats) = (0usize, 0usize);
        for (x, y) in &stream {
            f_feats += full.process(x, *y).evaluated;
            a_feats += att.process(x, *y).evaluated;
        }
        assert!(
            (a_feats as f64) < 0.5 * f_feats as f64,
            "attentive {a_feats} vs full {f_feats}: expected >2x savings"
        );
        // Comparable final accuracy.
        let test = separable_stream(200, dim);
        let err = |l: &BoundedPegasos<_>| {
            test.iter().filter(|(x, y)| y * l.full_margin(x) <= 0.0).count()
        };
        let fe = test.iter().filter(|(x, y)| *y * full.full_margin(x) <= 0.0).count();
        let ae = err(&att);
        assert!(ae <= fe + 10, "attentive err {ae} vs full err {fe}");
    }

    #[test]
    fn early_stopped_examples_do_not_update() {
        let dim = 16;
        let cfg = PegasosConfig { lambda: 0.01, policy: CoordinatePolicy::Sequential, ..Default::default() };
        let mut att = BoundedPegasos::new(dim, cfg, ConstantBoundary::new(0.2));
        let mut saw_early_stop = false;
        for (x, y) in separable_stream(800, dim) {
            let before = att.updates();
            let info = att.process(&x, y);
            if info.early_stopped {
                saw_early_stop = true;
                assert_eq!(att.updates(), before, "early stop must not update");
                assert!(!info.updated);
            }
        }
        assert!(saw_early_stop, "attentive learner never early-stopped on easy data");
    }

    #[test]
    fn update_counter_and_learning_rate_schedule() {
        let dim = 4;
        let mut l = Pegasos::full(dim, PegasosConfig { lambda: 0.5, project: false, ..Default::default() });
        // First update: mu = 1/(lambda*1) = 2, decay = 1 - 1 = 0 -> w = mu*y*x
        let x = [1.0, 2.0, 0.0, 0.0];
        let info = l.process(&x, 1.0);
        assert!(info.updated);
        assert!((l.weights()[0] - 2.0).abs() < 1e-12);
        assert!((l.weights()[1] - 4.0).abs() < 1e-12);
        assert_eq!(l.updates(), 1);
    }

    #[test]
    fn name_includes_boundary_and_policy() {
        let l = BoundedPegasos::new(4, PegasosConfig::default(), ConstantBoundary::new(0.1));
        assert_eq!(l.name(), "pegasos[constant-stst/weight-sampled]");
    }

    #[test]
    fn warm_start_restores_weights_and_survives_first_update() {
        let dim = 4;
        let lambda = 0.25;
        let cfg = PegasosConfig { lambda, ..Default::default() };
        let mut l = BoundedPegasos::new(dim, cfg, ConstantBoundary::new(0.1));
        l.warm_start(&[1.0, -1.0, 0.5, 0.0], 0.75);
        assert_eq!(l.weights(), &[1.0, -1.0, 0.5, 0.0]);
        // The clock resumes near 1/λ, so the first violation's decay is
        // 1 − 1/t ≈ 1 − λ, not 0: restored weights are damped, not erased.
        assert_eq!(l.updates(), (1.0 / lambda).round() as u64);
        let info = l.process(&[-1.0, 1.0, -1.0, 1.0], 1.0);
        assert!(info.updated, "a violating example still updates");
        assert!(
            l.weights().iter().any(|w| w.abs() > 1e-6),
            "warm-started weights must survive the first update"
        );
    }

    #[test]
    fn warm_start_is_a_no_op_on_zero_or_malformed_snapshots() {
        let cfg = PegasosConfig { lambda: 0.01, ..Default::default() };
        let fresh = BoundedPegasos::new(4, cfg, ConstantBoundary::new(0.1));
        let mut l = fresh.clone();
        l.warm_start(&[0.0; 4], 4.0); // all-zero: stay cold
        assert_eq!(l.weights(), fresh.weights());
        assert_eq!(l.updates(), 0, "zero snapshot must not advance the clock");
        l.warm_start(&[1.0; 3], 4.0); // wrong dim: ignored
        assert_eq!(l.updates(), 0);
        l.warm_start(&[1.0, f64::NAN, 0.0, 0.0], 4.0); // non-finite: ignored
        assert_eq!(l.updates(), 0);
    }

    #[test]
    fn warm_start_projects_an_oversized_snapshot_onto_the_ball() {
        let lambda = 1.0; // ball radius 1
        let cfg = PegasosConfig { lambda, ..Default::default() };
        let mut l = BoundedPegasos::new(2, cfg, ConstantBoundary::new(0.1));
        l.warm_start(&[3.0, 4.0], 1.0); // norm 5 > 1
        let norm = l.weights().iter().map(|w| w * w).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-12, "projected norm {norm}");
        // Direction is preserved.
        assert!((l.weights()[0] / l.weights()[1] - 0.75).abs() < 1e-12);
    }
}
