//! All-pairs (1-vs-1) multiclass ensemble of attentive binary learners.
//!
//! The paper evaluates single 1-vs-1 MNIST pairs; the natural deployment
//! is the classic all-pairs reduction: one binary learner per unordered
//! class pair, majority vote at prediction. The attention mechanism
//! compounds: each of the `C(C-1)/2` voters early-exits independently,
//! so an easy example costs a few dozen features *per voter* instead of
//! `n`, and the ensemble's feature budget stays sub-linear in both the
//! number of classes touched and the dimensionality.

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::learner::pegasos::{BoundedPegasos, PegasosConfig};
use crate::learner::OnlineLearner;
use crate::stst::boundary::AnyBoundary;

/// One-vs-one multiclass ensemble over attentive Pegasos voters.
pub struct OneVsOneEnsemble {
    classes: Vec<i64>,
    /// Voter for each pair `(classes[a], classes[b])`, a < b; +1 margin
    /// votes for `classes[a]`.
    voters: Vec<((i64, i64), BoundedPegasos<AnyBoundary>)>,
}

impl OneVsOneEnsemble {
    /// Build voters for every unordered pair of `classes`.
    pub fn new(
        dim: usize,
        classes: &[i64],
        cfg: PegasosConfig,
        boundary: AnyBoundary,
    ) -> Result<Self> {
        if classes.len() < 2 {
            return Err(Error::Config("multiclass needs >= 2 classes".into()));
        }
        let mut sorted = classes.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let mut voters = Vec::new();
        for a in 0..sorted.len() {
            for b in a + 1..sorted.len() {
                let seed = cfg.seed
                    ^ (a as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (b as u64).wrapping_mul(0xA076_1D64_78BD_642F);
                let vcfg = PegasosConfig { seed, ..cfg };
                voters.push((
                    (sorted[a], sorted[b]),
                    BoundedPegasos::new(dim, vcfg, boundary.clone()),
                ));
            }
        }
        Ok(Self { classes: sorted, voters })
    }

    /// Classes the ensemble distinguishes.
    pub fn classes(&self) -> &[i64] {
        &self.classes
    }

    /// Number of binary voters (`C(C-1)/2`).
    pub fn voter_count(&self) -> usize {
        self.voters.len()
    }

    /// Mutable view of the voters in pair-enumeration order (`(a, b)`
    /// with `a < b` over the sorted classes). Mutable because reading a
    /// voter's serving statistics (`var_sn`) refreshes its variance
    /// cache — this is how
    /// [`crate::coordinator::service::EnsembleSnapshot::from_trained`]
    /// snapshots the ensemble for serving.
    pub fn voters_mut(
        &mut self,
    ) -> impl Iterator<Item = (&(i64, i64), &mut BoundedPegasos<AnyBoundary>)> {
        self.voters.iter_mut().map(|(pair, learner)| (&*pair, learner))
    }

    /// One online pass over a multiclass dataset in the given row order.
    /// Each example trains only the `C-1` voters whose pair contains its
    /// label. Returns total feature evaluations spent.
    pub fn train_pass(&mut self, ds: &Dataset, order: &[usize]) -> u64 {
        let mut features = 0u64;
        for &i in order {
            let ex = ds.get(i);
            for ((pos, neg), learner) in self.voters.iter_mut() {
                let y = if ex.label == *pos {
                    1.0
                } else if ex.label == *neg {
                    -1.0
                } else {
                    continue;
                };
                features += learner.process(ex.features, y).evaluated as u64;
            }
        }
        features
    }

    /// Predict with early-stopped voters; returns `(class, features)`.
    /// Ties break toward the smaller class label (deterministic).
    pub fn predict(&mut self, x: &[f64]) -> (i64, usize) {
        let mut votes: Vec<(i64, u32)> = self.classes.iter().map(|&c| (c, 0)).collect();
        let mut features = 0usize;
        for ((pos, neg), learner) in self.voters.iter_mut() {
            let (score, k) = learner.predict_early(x);
            features += k;
            let winner = if score >= 0.0 { *pos } else { *neg };
            if let Some(v) = votes.iter_mut().find(|(c, _)| *c == winner) {
                v.1 += 1;
            }
        }
        let best = votes.iter().max_by_key(|(c, v)| (*v, -*c)).map(|(c, _)| *c).unwrap();
        (best, features)
    }

    /// Accuracy + mean features per prediction over a dataset.
    pub fn evaluate(&mut self, ds: &Dataset) -> (f64, f64) {
        if ds.is_empty() {
            return (0.0, 0.0);
        }
        let mut correct = 0usize;
        let mut features = 0usize;
        for ex in ds.iter() {
            let (pred, k) = {
                let e = ex;
                self.predict(e.features)
            };
            features += k;
            if pred == ex.label {
                correct += 1;
            }
        }
        (correct as f64 / ds.len() as f64, features as f64 / ds.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::ShuffledIndices;
    use crate::data::synth::SynthDigits;

    fn cfg() -> PegasosConfig {
        PegasosConfig { lambda: 1e-2, ..Default::default() }
    }

    #[test]
    fn pair_enumeration() {
        let e = OneVsOneEnsemble::new(
            4,
            &[3, 1, 2, 1],
            cfg(),
            AnyBoundary::Full,
        )
        .unwrap();
        assert_eq!(e.classes(), &[1, 2, 3]);
        assert_eq!(e.voter_count(), 3);
        assert!(OneVsOneEnsemble::new(4, &[1], cfg(), AnyBoundary::Full).is_err());
    }

    #[test]
    fn three_class_digits_learned_with_attention() {
        let classes = [1i64, 2, 3];
        let ds = SynthDigits::new(31).generate_classes(2_400, &[1, 2, 3]);
        let (train, test) = ds.split(0.8);
        let mut ens = OneVsOneEnsemble::new(
            train.dim(),
            &classes,
            cfg(),
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        )
        .unwrap();
        let order = ShuffledIndices::new(train.len(), 0).epoch(0);
        let spent = ens.train_pass(&train, &order);
        // Attention: per (example, voter) cost must be well under dim.
        let per_voter = spent as f64 / (train.len() as f64 * 2.0); // 2 voters/example
        assert!(per_voter < 784.0 * 0.7, "per-voter features {per_voter:.0}");
        let (acc, feats) = ens.evaluate(&test);
        assert!(acc > 0.85, "3-class accuracy {acc}");
        assert!(
            feats < 3.0 * 784.0 * 0.8,
            "ensemble prediction features {feats:.0} should early-exit"
        );
    }

    #[test]
    fn full_ensemble_matches_or_beats_attentive_cost() {
        let classes = [2i64, 3];
        let ds = SynthDigits::new(32).generate_classes(800, &[2, 3]);
        let (train, test) = ds.split(0.8);
        let order = ShuffledIndices::new(train.len(), 1).epoch(0);

        let mut full =
            OneVsOneEnsemble::new(train.dim(), &classes, cfg(), AnyBoundary::Full).unwrap();
        let f_spent = full.train_pass(&train, &order);
        let (f_acc, _) = full.evaluate(&test);

        let mut att = OneVsOneEnsemble::new(
            train.dim(),
            &classes,
            cfg(),
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        )
        .unwrap();
        let a_spent = att.train_pass(&train, &order);
        let (a_acc, _) = att.evaluate(&test);

        assert!(a_spent < f_spent, "attentive ensemble must spend less: {a_spent} vs {f_spent}");
        assert!(a_acc >= f_acc - 0.1, "attentive acc {a_acc} vs full {f_acc}");
    }
}
