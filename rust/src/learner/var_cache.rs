//! Incremental maintenance of `var(S_n) = Σ_j w_j² var_y(x_j)`.
//!
//! The boundary needs the full-sum variance *before* evaluating the
//! example — but computing the Σ from scratch is O(n), which would erase
//! the O(√n) win. [`VarCache`] keeps the sum per class and patches it:
//!
//! * when a feature observation changes `var̂_y(x_j)` (after a walk), the
//!   cached sum gains `w_j²·(var_new − var_old)` — O(evaluated);
//! * when the weight vector changes (Pegasos update — already O(n)),
//!   both sums are rebuilt — O(n), amortized over the many non-update
//!   examples;
//! * when the weight vector is only *scaled* (Pegasos projection /
//!   `(1−μλ)` decay alone), the sums scale by the factor squared — O(1).

use crate::stst::variance::ClassVariance;

/// Cached per-class `Σ w_j² var_y(x_j)` kept in sync with a
/// [`ClassVariance`] table and a weight vector.
#[derive(Debug, Clone)]
pub struct VarCache {
    /// Underlying per-(class, feature) estimator table.
    pub table: ClassVariance,
    sum_pos: f64,
    sum_neg: f64,
    dirty: bool,
    /// Per-coordinate stamp for within-example dedup (see
    /// [`Self::observe_prefix`]): `seen[j] == stamp` means coordinate `j`
    /// was already folded in for the current example.
    seen: Vec<u32>,
    stamp: u32,
}

impl VarCache {
    /// New cache over `dim` features (default warm-up prior).
    pub fn new(dim: usize) -> Self {
        Self {
            table: ClassVariance::new(dim),
            sum_pos: 0.0,
            sum_neg: 0.0,
            dirty: true,
            seen: vec![0; dim],
            stamp: 0,
        }
    }

    /// New cache whose unobserved features assume `prior_var` instead of
    /// the default prior — the warm-start path: a restored snapshot's
    /// `var_sn` says how much spread the previous run actually saw, and
    /// seeding the table with it keeps early stopping decisions honest
    /// until fresh observations take over.
    pub fn with_prior(dim: usize, prior_var: f64) -> Self {
        Self {
            table: ClassVariance::with_prior(dim, prior_var),
            sum_pos: 0.0,
            sum_neg: 0.0,
            dirty: true,
            seen: vec![0; dim],
            stamp: 0,
        }
    }

    /// Current `var(S_n)` for class `label`, rebuilding lazily if marked
    /// dirty.
    #[inline]
    pub fn var_sn(&mut self, label: f64, weights: &[f64]) -> f64 {
        if self.dirty {
            self.rebuild(weights);
        }
        if label >= 0.0 { self.sum_pos } else { self.sum_neg }
    }

    /// Force a full O(n) rebuild from `weights`.
    pub fn rebuild(&mut self, weights: &[f64]) {
        self.sum_pos = self.table.sum_variance(1.0, weights);
        self.sum_neg = self.table.sum_variance(-1.0, weights);
        self.dirty = false;
    }

    /// Mark the cache stale (arbitrary weight change).
    pub fn invalidate(&mut self) {
        self.dirty = true;
    }

    /// The weight vector was multiplied by `c` everywhere: sums scale by
    /// `c²` — O(1).
    pub fn on_weight_scale(&mut self, c: f64) {
        if !self.dirty {
            let c2 = c * c;
            self.sum_pos *= c2;
            self.sum_neg *= c2;
        }
    }

    /// Observe feature `j` of a `label` example with value `x`, patching
    /// the cached sum for that class — O(1).
    #[inline]
    pub fn observe(&mut self, label: f64, j: usize, x: f64, weights: &[f64]) {
        let old = self.table.var(label, j);
        self.table.observe(label, j, x);
        if !self.dirty {
            let w2 = weights[j] * weights[j];
            let delta = w2 * (self.table.var(label, j) - old);
            if label >= 0.0 {
                self.sum_pos += delta;
            } else {
                self.sum_neg += delta;
            }
        }
    }

    /// Observe the first `upto` visited coordinates (Algorithm 1's
    /// "Update var_{y}(x_j), j = 1..i"), folding each coordinate in **at
    /// most once per example**. With-replacement policies re-draw the same
    /// coordinate within one example; double-counting those identical
    /// values would deflate the class-conditional variance estimate (two
    /// equal observations have zero spread), making τ systematically too
    /// small and the test over-confident — measurably worse decision-error
    /// rates under the weight-sampled policy.
    pub fn observe_prefix(
        &mut self,
        label: f64,
        order: &[usize],
        xs: &[f64],
        upto: usize,
        weights: &[f64],
    ) {
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            // wrapped: clear stale stamps
            self.seen.iter_mut().for_each(|s| *s = 0);
            self.stamp = 1;
        }
        for &j in order.iter().take(upto) {
            if self.seen[j] != self.stamp {
                self.seen[j] = self.stamp;
                self.observe(label, j, xs[j], weights);
            }
        }
    }

    /// Exactness check (tests): cached vs recomputed gap.
    pub fn drift_from_exact(&mut self, weights: &[f64]) -> f64 {
        if self.dirty {
            self.rebuild(weights);
        }
        let exact_pos = self.table.sum_variance(1.0, weights);
        let exact_neg = self.table.sum_variance(-1.0, weights);
        (self.sum_pos - exact_pos).abs().max((self.sum_neg - exact_neg).abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_patches_exactly() {
        let w = vec![0.5, -2.0, 1.0];
        let mut vc = VarCache::new(3);
        vc.rebuild(&w);
        for (label, j, x) in [(1.0, 0, 0.3), (1.0, 0, -0.7), (1.0, 1, 0.9), (-1.0, 2, 0.1), (-1.0, 2, 0.8), (1.0, 0, 0.2)] {
            vc.observe(label, j, x, &w);
        }
        assert!(vc.drift_from_exact(&w) < 1e-12);
    }

    #[test]
    fn scale_patches_exactly() {
        let mut w = vec![1.0, 2.0, 3.0];
        let mut vc = VarCache::new(3);
        // give features some observed variance
        for x in [0.1, 0.9, 0.4] {
            vc.observe(1.0, 1, x, &w);
        }
        vc.rebuild(&w);
        let c = 0.85;
        w.iter_mut().for_each(|v| *v *= c);
        vc.on_weight_scale(c);
        assert!(vc.drift_from_exact(&w) < 1e-12);
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let mut w = vec![1.0, 1.0];
        let mut vc = VarCache::new(2);
        let v0 = vc.var_sn(1.0, &w);
        // prior variance 1/3 per feature * w² = 2/3
        assert!((v0 - 2.0 / 3.0).abs() < 1e-12);
        w[0] = 10.0;
        vc.invalidate();
        let v1 = vc.var_sn(1.0, &w);
        assert!((v1 - (100.0 + 1.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn per_class_sums_independent() {
        let w = vec![1.0; 2];
        let mut vc = VarCache::new(2);
        vc.rebuild(&w);
        // Drive pos-class feature 0 variance to ~0 by repetition
        for _ in 0..50 {
            vc.observe(1.0, 0, 0.42, &w);
        }
        let pos = vc.var_sn(1.0, &w);
        let neg = vc.var_sn(-1.0, &w);
        assert!(pos < neg, "pos {pos} should shrink below neg {neg}");
    }

    #[test]
    fn observe_prefix_dedups_within_example() {
        let w = vec![1.0, 1.0];
        let mut vc = VarCache::new(2);
        vc.rebuild(&w);
        let order = [0usize, 0, 1];
        let xs = [0.5, -0.5];
        vc.observe_prefix(1.0, &order, &xs, 3, &w);
        assert!(vc.drift_from_exact(&w) < 1e-12);
        // coordinate 0 drawn twice but observed once
        assert_eq!(vc.table.total_observations(), 2);
        // ...and the next example observes it again (stamp advanced)
        vc.observe_prefix(1.0, &order, &xs, 2, &w);
        assert_eq!(vc.table.total_observations(), 3);
    }
}
