//! (Attentive) Perceptron — the stopping rule beyond Pegasos.
//!
//! The paper argues the STST "applies to the majority of margin based
//! learning algorithms" and lists Rosenblatt's perceptron as the
//! canonical passive filter (`update iff y·⟨w,x⟩ ≤ 0`). Here the margin
//! threshold is θ = 0, which is exactly Theorem 1's simplified boundary
//! `τ = sqrt(var(S_n))·sqrt(log(1/√δ))`. One update: `w ← w + y x`.

use crate::margin::policy::OrderGenerator;
use crate::margin::walker::{WalkOutcome, Walker};
use crate::stst::boundary::Boundary;

use super::pegasos::PegasosConfig;
use super::var_cache::VarCache;
use super::{OnlineLearner, StepInfo};

/// Perceptron with sequential margin evaluation under boundary `B`.
/// Reuses [`PegasosConfig`] for policy/seed plumbing; `lambda` and
/// `project` are ignored, θ is forced to 0 (the perceptron's filter).
#[derive(Debug, Clone)]
pub struct BoundedPerceptron<B: Boundary> {
    cfg: PegasosConfig,
    boundary: B,
    w: Vec<f64>,
    updates: u64,
    vars: VarCache,
    orders: OrderGenerator,
    walker: Walker,
    orders_dirty: bool,
    visited: Vec<usize>,
}

impl<B: Boundary> BoundedPerceptron<B> {
    /// Fresh perceptron at `w = 0`.
    pub fn new(dim: usize, cfg: PegasosConfig, boundary: B) -> Self {
        let cfg = PegasosConfig { theta: 0.0, ..cfg };
        Self {
            cfg,
            boundary,
            w: vec![0.0; dim],
            updates: 0,
            vars: VarCache::new(dim),
            orders: OrderGenerator::new(cfg.policy, cfg.seed),
            walker: Walker::new(),
            orders_dirty: true,
            visited: Vec::with_capacity(dim),
        }
    }

    /// Updates performed (perceptron mistakes).
    pub fn updates(&self) -> u64 {
        self.updates
    }
}

impl<B: Boundary> OnlineLearner for BoundedPerceptron<B> {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn process(&mut self, x: &[f64], y: f64) -> StepInfo {
        if self.orders_dirty {
            self.orders.refresh(&self.w);
            self.orders_dirty = false;
        }
        let var_sn = self.vars.var_sn(y, &self.w);
        let mut visited = std::mem::take(&mut self.visited);
        let res = self.walker.walk_lazy(
            &self.w,
            x,
            y,
            &mut self.orders,
            0.0,
            var_sn,
            &self.boundary,
            &mut visited,
        );

        let info = match res.outcome {
            WalkOutcome::EarlyStopped => {
                self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                StepInfo {
                    evaluated: res.evaluated,
                    updated: false,
                    early_stopped: true,
                    margin: res.partial_margin,
                    mistake: false,
                    outcome: res.outcome,
                }
            }
            _ => {
                if self.boundary.is_evidence_based() {
                    self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                }
                let mistake = res.partial_margin <= 0.0;
                if mistake {
                    // w += y x (touches all coordinates; invalidate caches)
                    for (wj, &xj) in self.w.iter_mut().zip(x) {
                        *wj += y * xj;
                    }
                    self.updates += 1;
                    self.vars.invalidate();
                    self.orders_dirty = true;
                }
                StepInfo {
                    evaluated: res.evaluated,
                    updated: mistake,
                    early_stopped: false,
                    margin: res.partial_margin,
                    mistake,
                    outcome: res.outcome,
                }
            }
        };
        self.visited = visited;
        info
    }

    fn name(&self) -> String {
        format!("perceptron[{}/{}]", self.boundary.name(), self.cfg.policy.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::{ConstantBoundary, TrivialBoundary};

    fn stream(n: usize, dim: usize) -> Vec<(Vec<f64>, f64)> {
        (0..n)
            .map(|i| {
                let y = if i % 2 == 0 { 1.0 } else { -1.0 };
                let x: Vec<f64> =
                    (0..dim).map(|j| if j < dim / 2 { y * 0.9 } else { -y * 0.7 }).collect();
                (x, y)
            })
            .collect()
    }

    #[test]
    fn perceptron_converges_on_separable() {
        let dim = 10;
        let mut p = BoundedPerceptron::new(
            dim,
            PegasosConfig { policy: CoordinatePolicy::Sequential, ..Default::default() },
            TrivialBoundary,
        );
        for (x, y) in stream(200, dim) {
            p.process(&x, y);
        }
        for (x, y) in stream(50, dim) {
            assert!(y * p.full_margin(&x) > 0.0);
        }
        // Perceptron mistake bound: finite updates on separable data.
        assert!(p.updates() < 20);
    }

    #[test]
    fn attentive_perceptron_saves_features() {
        let dim = 64;
        let cfg = PegasosConfig { policy: CoordinatePolicy::Sequential, ..Default::default() };
        let mut full = BoundedPerceptron::new(dim, cfg, TrivialBoundary);
        let mut att = BoundedPerceptron::new(dim, cfg, ConstantBoundary::new(0.1));
        let (mut ff, mut af) = (0usize, 0usize);
        for (x, y) in stream(600, dim) {
            ff += full.process(&x, y).evaluated;
            af += att.process(&x, y).evaluated;
        }
        assert!(af < ff / 2, "attentive perceptron {af} vs full {ff}");
    }

    #[test]
    fn theta_forced_to_zero() {
        let p = BoundedPerceptron::new(
            4,
            PegasosConfig { theta: 5.0, ..Default::default() },
            TrivialBoundary,
        );
        assert_eq!(p.cfg.theta, 0.0);
    }
}
