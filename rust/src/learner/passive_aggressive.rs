//! (Attentive) Passive-Aggressive — PA-I of Crammer et al. 2006 under a
//! stopping boundary.
//!
//! PA is the paper's other named "passive online algorithm with a margin
//! based filtering criterion": update iff the hinge loss
//! `ℓ = max(0, 1 − y·⟨w,x⟩)` is positive, with step
//! `τ_pa = min(C, ℓ/‖x‖²)` and `w ← w + τ_pa·y·x`. The attentive variant
//! runs the same Constant STST filter at θ = 1 before committing to the
//! full margin evaluation.

use crate::margin::policy::OrderGenerator;
use crate::margin::walker::{WalkOutcome, Walker};
use crate::stst::boundary::Boundary;

use super::pegasos::PegasosConfig;
use super::var_cache::VarCache;
use super::{OnlineLearner, StepInfo};

/// PA-I with sequential margin evaluation under boundary `B`.
/// `cfg.lambda` is reused as the PA aggressiveness cap `C = 1/λ`-style;
/// see [`BoundedPa::new`].
#[derive(Debug, Clone)]
pub struct BoundedPa<B: Boundary> {
    cfg: PegasosConfig,
    /// Aggressiveness parameter C (PA-I cap).
    pub c: f64,
    boundary: B,
    w: Vec<f64>,
    updates: u64,
    vars: VarCache,
    orders: OrderGenerator,
    walker: Walker,
    orders_dirty: bool,
    visited: Vec<usize>,
}

impl<B: Boundary> BoundedPa<B> {
    /// Fresh PA-I learner with aggressiveness `c`; θ comes from `cfg`
    /// (default 1.0, the PA hinge).
    pub fn new(dim: usize, cfg: PegasosConfig, c: f64, boundary: B) -> Self {
        assert!(c > 0.0, "PA aggressiveness C must be positive");
        Self {
            cfg,
            c,
            boundary,
            w: vec![0.0; dim],
            updates: 0,
            vars: VarCache::new(dim),
            orders: OrderGenerator::new(cfg.policy, cfg.seed),
            walker: Walker::new(),
            orders_dirty: true,
            visited: Vec::with_capacity(dim),
        }
    }

    /// Updates performed.
    pub fn updates(&self) -> u64 {
        self.updates
    }
}

impl<B: Boundary> OnlineLearner for BoundedPa<B> {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn process(&mut self, x: &[f64], y: f64) -> StepInfo {
        if self.orders_dirty {
            self.orders.refresh(&self.w);
            self.orders_dirty = false;
        }
        let var_sn = self.vars.var_sn(y, &self.w);
        let mut visited = std::mem::take(&mut self.visited);
        let res = self.walker.walk_lazy(
            &self.w,
            x,
            y,
            &mut self.orders,
            self.cfg.theta,
            var_sn,
            &self.boundary,
            &mut visited,
        );

        let info = match res.outcome {
            WalkOutcome::EarlyStopped => {
                self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                StepInfo {
                    evaluated: res.evaluated,
                    updated: false,
                    early_stopped: true,
                    margin: res.partial_margin,
                    mistake: false,
                    outcome: res.outcome,
                }
            }
            _ => {
                if self.boundary.is_evidence_based() {
                    self.vars.observe_prefix(y, &visited, x, res.evaluated, &self.w);
                }
                let loss = (self.cfg.theta - res.partial_margin).max(0.0);
                let mistake = res.partial_margin <= 0.0;
                let updated = loss > 0.0;
                if updated {
                    let norm_sq: f64 = x.iter().map(|v| v * v).sum();
                    if norm_sq > 0.0 {
                        let step = (loss / norm_sq).min(self.c);
                        for (wj, &xj) in self.w.iter_mut().zip(x) {
                            *wj += step * y * xj;
                        }
                        self.updates += 1;
                        self.vars.invalidate();
                        self.orders_dirty = true;
                    }
                }
                StepInfo {
                    evaluated: res.evaluated,
                    updated,
                    early_stopped: false,
                    margin: res.partial_margin,
                    mistake,
                    outcome: res.outcome,
                }
            }
        };
        self.visited = visited;
        info
    }

    fn name(&self) -> String {
        format!("pa1[{}/{}]", self.boundary.name(), self.cfg.policy.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::{ConstantBoundary, TrivialBoundary};

    fn stream(n: usize, dim: usize) -> Vec<(Vec<f64>, f64)> {
        (0..n)
            .map(|i| {
                let y = if i % 3 == 0 { -1.0 } else { 1.0 };
                let x: Vec<f64> =
                    (0..dim).map(|j| if j % 2 == 0 { y * 0.8 } else { -y * 0.3 }).collect();
                (x, y)
            })
            .collect()
    }

    #[test]
    fn pa_achieves_margin_on_separable() {
        let dim = 8;
        let mut l = BoundedPa::new(
            dim,
            PegasosConfig { policy: CoordinatePolicy::Sequential, ..Default::default() },
            10.0,
            TrivialBoundary,
        );
        for (x, y) in stream(300, dim) {
            l.process(&x, y);
        }
        for (x, y) in stream(30, dim) {
            assert!(y * l.full_margin(&x) > 0.5, "PA should achieve solid margins");
        }
    }

    #[test]
    fn pa_step_capped_by_c() {
        let dim = 2;
        let c = 0.001;
        let mut l = BoundedPa::new(dim, PegasosConfig::default(), c, TrivialBoundary);
        l.process(&[1.0, 0.0], 1.0);
        // step = min(C, loss/normsq) = C here; w0 = C
        assert!((l.weights()[0] - c).abs() < 1e-12);
    }

    #[test]
    fn attentive_pa_saves_features_on_confident_examples() {
        // PA-I converges to margins hugging exactly θ = 1, so in-sample
        // examples rarely clear θ + τ — the filter correctly stays out of
        // the way there. Early stopping must fire on *confidently* correct
        // inputs (margin well above θ), e.g. scaled-up examples.
        let dim = 64;
        let cfg = PegasosConfig { policy: CoordinatePolicy::Sequential, ..Default::default() };
        let mut att = BoundedPa::new(dim, cfg, 10.0, ConstantBoundary::new(0.1));
        for (x, y) in stream(400, dim) {
            att.process(&x, y);
        }
        // Scale a training-like example 4x: margin ≈ 4 ≫ 1 + τ.
        let (x, y) = stream(1, dim).pop().unwrap();
        let x4: Vec<f64> = x.iter().map(|v| v * 4.0).collect();
        let info = att.process(&x4, y);
        assert!(info.early_stopped, "confident example should stop early");
        assert!(info.evaluated < dim, "stopped at {}", info.evaluated);
        // And the attentive variant never does MORE work than full.
        let mut full = BoundedPa::new(dim, cfg, 10.0, TrivialBoundary);
        let mut att2 = BoundedPa::new(dim, cfg, 10.0, ConstantBoundary::new(0.1));
        let (mut ff, mut af) = (0usize, 0usize);
        for (x, y) in stream(400, dim) {
            ff += full.process(&x, y).evaluated;
            af += att2.process(&x, y).evaluated;
        }
        assert!(af <= ff, "attentive PA must not exceed full: {af} vs {ff}");
    }

    #[test]
    fn zero_example_does_not_update() {
        let mut l = BoundedPa::new(3, PegasosConfig::default(), 1.0, TrivialBoundary);
        let info = l.process(&[0.0, 0.0, 0.0], 1.0);
        // loss = 1 > 0 but ||x||² = 0: no step possible
        assert!(l.weights().iter().all(|&w| w == 0.0));
        assert!(info.updated); // loss positive, counted as violating...
        assert_eq!(l.updates(), 0); // ...but no actual step taken
    }
}
