//! Attentive Pegasos — the paper's Algorithm 1.
//!
//! A thin, documented facade over [`BoundedPegasos`] with the Constant
//! STST boundary: the learner that "computes in the order of O(√n)
//! features" per example while matching full Pegasos's generalization.
//! Provided as its own module so the public API mirrors the paper's
//! naming; [`AttentiveAnyPegasos`] is the runtime-dispatched variant the
//! CLI uses.

use crate::learner::pegasos::{BoundedPegasos, PegasosConfig};
use crate::stst::boundary::{AnyBoundary, ConstantBoundary, CurvedBoundary};

/// Attentive Pegasos: Pegasos + Constant STST (Algorithm 1).
pub type AttentivePegasos = BoundedPegasos<ConstantBoundary>;

/// Pegasos under the conservative Curved STST (prior-work boundary).
pub type CurvedPegasos = BoundedPegasos<CurvedBoundary>;

/// Pegasos with a boundary chosen at runtime (CLI / config files).
pub type AttentiveAnyPegasos = BoundedPegasos<AnyBoundary>;

/// Convenience constructor matching the paper's parameterization:
/// dimensionality, λ, and decision-error rate δ.
pub fn attentive_pegasos(dim: usize, lambda: f64, delta: f64) -> AttentivePegasos {
    BoundedPegasos::new(
        dim,
        PegasosConfig { lambda, ..Default::default() },
        ConstantBoundary::new(delta),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::OnlineLearner;

    #[test]
    fn constructor_wires_delta_and_lambda() {
        let l = attentive_pegasos(784, 1e-4, 0.1);
        assert_eq!(l.dim(), 784);
        assert!((l.boundary().delta - 0.1).abs() < 1e-12);
        assert!((l.config().lambda - 1e-4).abs() < 1e-18);
        assert!(l.name().starts_with("pegasos[constant-stst"));
    }
}
