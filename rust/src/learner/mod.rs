//! Margin-based online learners with stochastic focus of attention.
//!
//! The paper's Algorithm 1 (Attentive Pegasos) and the surrounding cast:
//!
//! * [`pegasos`] — the generic boundary-parameterized Pegasos core
//!   ([`pegasos::BoundedPegasos`]); with the trivial boundary it *is*
//!   vanilla Pegasos (Shalev-Shwartz et al. 2010).
//! * [`attentive`] — Attentive Pegasos: the Constant STST boundary.
//! * [`budgeted`] — Budgeted Pegasos: fixed-k baseline (green curves).
//! * [`perceptron`] / [`passive_aggressive`] — the same attentive
//!   treatment applied to Rosenblatt's perceptron and PA-I, backing the
//!   paper's claim that the stopping rule "applies to the majority of
//!   margin based learning algorithms".
//! * [`var_cache`] — incremental maintenance of `var(S_n)` so the
//!   boundary costs O(1) per coordinate.
//! * [`predictor`] — early-stopped *prediction* (the paper's right
//!   subfigures): two-sided STST on the sign of the margin.
//! * [`multiclass`] — all-pairs 1-vs-1 ensemble of attentive voters
//!   (the natural MNIST deployment; extension beyond the paper's
//!   single-pair experiments).

pub mod attentive;
pub mod budgeted;
pub mod multiclass;
pub mod passive_aggressive;
pub mod pegasos;
pub mod perceptron;
pub mod predictor;
pub mod var_cache;

use crate::margin::walker::WalkOutcome;

/// What one online step did — the trainer's bookkeeping currency.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// Feature evaluations spent on this example.
    pub evaluated: usize,
    /// Did the model update?
    pub updated: bool,
    /// Was the example skipped via the stopping boundary?
    pub early_stopped: bool,
    /// Signed margin `y·⟨w,x⟩` at decision time (partial if stopped).
    pub margin: f64,
    /// Was the (partial) prediction a mistake (`y·margin ≤ 0`)?
    pub mistake: bool,
    /// Raw walk outcome.
    pub outcome: WalkOutcome,
}

/// A margin-based online learner consuming a stream of (x, y∈{±1}).
pub trait OnlineLearner: Send {
    /// Feature dimensionality.
    fn dim(&self) -> usize;

    /// Current weight vector.
    fn weights(&self) -> &[f64];

    /// Consume one example: sequentially evaluate its margin under the
    /// learner's boundary and update the model if warranted.
    fn process(&mut self, x: &[f64], y: f64) -> StepInfo;

    /// Full (dense) margin `⟨w, x⟩` — used for test-set evaluation and
    /// decision-error audits.
    fn full_margin(&self, x: &[f64]) -> f64 {
        crate::margin::dot(self.weights(), x)
    }

    /// Predict with the learner's own early-stopping rule; returns
    /// `(score, features_evaluated)`. Default: full computation.
    fn predict_early(&mut self, x: &[f64]) -> (f64, usize) {
        (self.full_margin(x), self.dim())
    }

    /// Human-readable identity (algorithm + boundary), for reports.
    fn name(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::pegasos::{BoundedPegasos, PegasosConfig};
    use crate::stst::boundary::TrivialBoundary;

    #[test]
    fn default_predict_early_is_full() {
        let mut l = BoundedPegasos::new(4, PegasosConfig::default(), TrivialBoundary);
        // Force some weights via an update.
        l.process(&[1.0, 0.0, 0.0, 0.0], 1.0);
        let x = [1.0, 2.0, 3.0, 4.0];
        let (score, k) = l.predict_early(&x);
        assert_eq!(k, 4);
        assert!((score - l.full_margin(&x)).abs() < 1e-12);
    }
}
