//! Seeded streaming/shuffling over datasets and tasks.
//!
//! Online learners consume examples in stream order; the paper averages
//! over 10 random permutations of the dataset. [`ShuffledIndices`]
//! produces those permutations deterministically per `(seed, epoch)` so
//! every run — and every parallel shard — is reproducible.

use crate::util::rng::Rng64;

/// Deterministic permutation generator over `0..len`.
#[derive(Debug, Clone)]
pub struct ShuffledIndices {
    len: usize,
    seed: u64,
}

impl ShuffledIndices {
    /// Permutations of `0..len` derived from `seed`.
    pub fn new(len: usize, seed: u64) -> Self {
        Self { len, seed }
    }

    /// The permutation for `epoch` (Fisher–Yates, ChaCha8 keyed on
    /// `(seed, epoch)`).
    pub fn epoch(&self, epoch: u64) -> Vec<usize> {
        let mut rng = Rng64::seed_from_u64(self.seed ^ epoch.wrapping_mul(0x9E3779B97F4A7C15));
        let mut idx: Vec<usize> = (0..self.len).collect();
        rng.shuffle(&mut idx);
        idx
    }

    /// Iterator over `epochs` permutations chained into one stream.
    pub fn stream(&self, epochs: u64) -> impl Iterator<Item = usize> + '_ {
        (0..epochs).flat_map(move |e| self.epoch(e).into_iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_is_permutation() {
        let s = ShuffledIndices::new(50, 7);
        let p = s.epoch(0);
        assert_eq!(p.len(), 50);
        assert_eq!(p.iter().copied().collect::<HashSet<_>>().len(), 50);
    }

    #[test]
    fn deterministic_per_seed_and_epoch() {
        let a = ShuffledIndices::new(30, 1).epoch(2);
        let b = ShuffledIndices::new(30, 1).epoch(2);
        assert_eq!(a, b);
        assert_ne!(a, ShuffledIndices::new(30, 1).epoch(3));
        assert_ne!(a, ShuffledIndices::new(30, 2).epoch(2));
    }

    #[test]
    fn stream_chains_epochs() {
        let s = ShuffledIndices::new(5, 3);
        let all: Vec<usize> = s.stream(2).collect();
        assert_eq!(all.len(), 10);
        assert_eq!(&all[..5], s.epoch(0).as_slice());
        assert_eq!(&all[5..], s.epoch(1).as_slice());
    }

    #[test]
    fn empty_and_singleton() {
        assert!(ShuffledIndices::new(0, 0).epoch(0).is_empty());
        assert_eq!(ShuffledIndices::new(1, 0).epoch(5), vec![0]);
    }
}
