//! MNIST IDX-format reader.
//!
//! When real MNIST files (`train-images-idx3-ubyte`,
//! `train-labels-idx1-ubyte`, optionally `.gz`-decompressed) are placed in
//! a directory, [`load_mnist_dir`] reads them and the whole pipeline runs
//! on the genuine data instead of the synthetic stand-in. IDX is the
//! classic big-endian format: magic `0x00000803` (u8 tensor, 3 dims) for
//! images, `0x00000801` for labels.

use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::dataset::Dataset;

fn read_u32_be(buf: &[u8], off: usize) -> Result<u32> {
    buf.get(off..off + 4)
        .map(|b| u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or_else(|| Error::format("idx header", "truncated"))
}

/// Parse an IDX3 (images) byte buffer into `(rows, height, width, pixels)`.
pub fn parse_idx3(buf: &[u8]) -> Result<(usize, usize, usize, &[u8])> {
    let magic = read_u32_be(buf, 0)?;
    if magic != 0x0000_0803 {
        return Err(Error::format("idx3 magic", format!("expected 0x803, got {magic:#x}")));
    }
    let n = read_u32_be(buf, 4)? as usize;
    let h = read_u32_be(buf, 8)? as usize;
    let w = read_u32_be(buf, 12)? as usize;
    let need = 16 + n * h * w;
    if buf.len() < need {
        return Err(Error::format("idx3 body", format!("need {need} bytes, have {}", buf.len())));
    }
    Ok((n, h, w, &buf[16..need]))
}

/// Parse an IDX1 (labels) byte buffer into label bytes.
pub fn parse_idx1(buf: &[u8]) -> Result<&[u8]> {
    let magic = read_u32_be(buf, 0)?;
    if magic != 0x0000_0801 {
        return Err(Error::format("idx1 magic", format!("expected 0x801, got {magic:#x}")));
    }
    let n = read_u32_be(buf, 4)? as usize;
    let need = 8 + n;
    if buf.len() < need {
        return Err(Error::format("idx1 body", format!("need {need} bytes, have {}", buf.len())));
    }
    Ok(&buf[8..need])
}

fn read_file(path: &Path) -> Result<Vec<u8>> {
    let mut f = File::open(path).map_err(|e| Error::io(path, e))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(|e| Error::io(path, e))?;
    Ok(buf)
}

/// Load an images+labels IDX pair into a [`Dataset`] with pixel
/// intensities mapped to the paper's `[−1, 1]` range.
pub fn load_idx_pair(images: &Path, labels: &Path) -> Result<Dataset> {
    let img_buf = read_file(images)?;
    let lab_buf = read_file(labels)?;
    let (n, h, w, pixels) = parse_idx3(&img_buf)?;
    let labs = parse_idx1(&lab_buf)?;
    if labs.len() != n {
        return Err(Error::format(
            "idx pair",
            format!("{n} images but {} labels", labs.len()),
        ));
    }
    let dim = h * w;
    let mut ds = Dataset::new(dim);
    let mut row = vec![0.0f64; dim];
    for i in 0..n {
        for (j, &p) in pixels[i * dim..(i + 1) * dim].iter().enumerate() {
            row[j] = (p as f64) / 255.0; // [0,255] -> [0,1] ⊂ [−1,1]
        }
        ds.push(&row, labs[i] as i64)?;
    }
    Ok(ds)
}

/// Look for MNIST train files in `dir` and load them if present.
/// Returns `Ok(None)` when the files are absent (callers fall back to the
/// synthetic generator), `Err` on malformed files.
pub fn load_mnist_dir(dir: &Path) -> Result<Option<Dataset>> {
    let images: PathBuf = dir.join("train-images-idx3-ubyte");
    let labels: PathBuf = dir.join("train-labels-idx1-ubyte");
    if !images.exists() || !labels.exists() {
        return Ok(None);
    }
    load_idx_pair(&images, &labels).map(Some)
}

/// Serialize a dataset back to an IDX pair (used by tests and by
/// `attentive export-idx` to snapshot synthetic data for other tools).
/// Features are mapped from `[−1,1]` back to `[0,255]`.
pub fn write_idx_pair(ds: &Dataset, side: usize, images: &Path, labels: &Path) -> Result<()> {
    use std::io::Write;
    if side * side != ds.dim() {
        return Err(Error::Config(format!("side {side}² != dim {}", ds.dim())));
    }
    let n = ds.len() as u32;
    let mut img = Vec::with_capacity(16 + ds.len() * ds.dim());
    img.extend_from_slice(&0x0000_0803u32.to_be_bytes());
    img.extend_from_slice(&n.to_be_bytes());
    img.extend_from_slice(&(side as u32).to_be_bytes());
    img.extend_from_slice(&(side as u32).to_be_bytes());
    for &v in ds.features_raw() {
        img.push((v * 255.0).round().clamp(0.0, 255.0) as u8);
    }
    let mut lab = Vec::with_capacity(8 + ds.len());
    lab.extend_from_slice(&0x0000_0801u32.to_be_bytes());
    lab.extend_from_slice(&n.to_be_bytes());
    for &l in ds.labels() {
        lab.push(l as u8);
    }
    let mut f = File::create(images).map_err(|e| Error::io(images, e))?;
    f.write_all(&img).map_err(|e| Error::io(images, e))?;
    let mut f = File::create(labels).map_err(|e| Error::io(labels, e))?;
    f.write_all(&lab).map_err(|e| Error::io(labels, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthDigits;

    #[test]
    fn idx_round_trip() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let ds = SynthDigits::new(11).generate(25);
        let img = dir.path().join("train-images-idx3-ubyte");
        let lab = dir.path().join("train-labels-idx1-ubyte");
        write_idx_pair(&ds, 28, &img, &lab).unwrap();
        let loaded = load_mnist_dir(dir.path()).unwrap().expect("files exist");
        assert_eq!(loaded.len(), 25);
        assert_eq!(loaded.dim(), 784);
        assert_eq!(loaded.labels(), ds.labels());
        // Quantization to u8 loses < 1/255 per pixel.
        for i in 0..ds.len() {
            for (a, b) in ds.get(i).features.iter().zip(loaded.get(i).features) {
                assert!((a - b).abs() < 1.0 / 254.0, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn absent_dir_returns_none() {
        let dir = crate::util::tempdir::TempDir::new("t");
        assert!(load_mnist_dir(dir.path()).unwrap().is_none());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = vec![0u8; 32];
        buf[3] = 0x99;
        assert!(parse_idx3(&buf).is_err());
        assert!(parse_idx1(&buf).is_err());
    }

    #[test]
    fn truncated_body_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        buf.extend_from_slice(&2u32.to_be_bytes()); // 2 images
        buf.extend_from_slice(&28u32.to_be_bytes());
        buf.extend_from_slice(&28u32.to_be_bytes());
        buf.extend_from_slice(&[0u8; 100]); // far too short
        assert!(parse_idx3(&buf).is_err());
    }

    #[test]
    fn label_count_mismatch_rejected() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let ds = SynthDigits::new(1).generate(3);
        let img = dir.path().join("i");
        let lab = dir.path().join("l");
        write_idx_pair(&ds, 28, &img, &lab).unwrap();
        // Corrupt the label count.
        let mut lb = std::fs::read(&lab).unwrap();
        lb[7] = 99;
        std::fs::write(&lab, &lb).unwrap();
        assert!(load_idx_pair(&img, &lab).is_err());
    }
}
