//! libsvm / svmlight text format I/O.
//!
//! `label idx:val idx:val ...` with 1-based indices — the lingua franca
//! for margin-based learners (Pegasos's original release consumed it).
//! Reading densifies into [`Dataset`]; writing sparsifies (zeros skipped).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};

use super::dataset::Dataset;

/// Parse libsvm text. `dim` fixes the dense width; feature indices beyond
/// it are an error. Labels may be any integers (e.g. ±1 or digits).
pub fn parse(reader: impl BufRead, dim: usize) -> Result<Dataset> {
    let mut ds = Dataset::new(dim);
    let mut row = vec![0.0f64; dim];
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| Error::io("<libsvm stream>", e))?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        row.iter_mut().for_each(|v| *v = 0.0);
        let mut parts = line.split_whitespace();
        let label: i64 = parts
            .next()
            .ok_or_else(|| Error::format(format!("libsvm line {}", lineno + 1), "empty"))?
            .parse()
            .map_err(|e| {
                Error::format(format!("libsvm line {}", lineno + 1), format!("bad label: {e}"))
            })?;
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| {
                Error::format(format!("libsvm line {}", lineno + 1), format!("bad pair {tok:?}"))
            })?;
            let idx: usize = idx_s.parse().map_err(|e| {
                Error::format(format!("libsvm line {}", lineno + 1), format!("bad index: {e}"))
            })?;
            let val: f64 = val_s.parse().map_err(|e| {
                Error::format(format!("libsvm line {}", lineno + 1), format!("bad value: {e}"))
            })?;
            if idx == 0 || idx > dim {
                return Err(Error::format(
                    format!("libsvm line {}", lineno + 1),
                    format!("index {idx} out of 1..={dim}"),
                ));
            }
            row[idx - 1] = val;
        }
        ds.push(&row, label)?;
    }
    Ok(ds)
}

/// Read a libsvm file.
pub fn read_file(path: &Path, dim: usize) -> Result<Dataset> {
    let f = File::open(path).map_err(|e| Error::io(path, e))?;
    parse(BufReader::new(f), dim)
}

/// Write a dataset as libsvm text (zeros omitted; 1-based indices).
pub fn write_file(ds: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).map_err(|e| Error::io(path, e))?;
    let mut w = BufWriter::new(f);
    for ex in ds.iter() {
        write!(w, "{}", ex.label).map_err(|e| Error::io(path, e))?;
        for (j, &v) in ex.features.iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v).map_err(|e| Error::io(path, e))?;
            }
        }
        writeln!(w).map_err(|e| Error::io(path, e))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let text = "1 1:0.5 3:-2\n-1 2:1.25\n\n# comment only\n1 1:1 # trailing\n";
        let ds = parse(Cursor::new(text), 3).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.get(0).features, &[0.5, 0.0, -2.0]);
        assert_eq!(ds.get(1).features, &[0.0, 1.25, 0.0]);
        assert_eq!(ds.get(1).label, -1);
        assert_eq!(ds.get(2).features, &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse(Cursor::new("x 1:1\n"), 2).is_err(), "bad label");
        assert!(parse(Cursor::new("1 0:1\n"), 2).is_err(), "index 0");
        assert!(parse(Cursor::new("1 3:1\n"), 2).is_err(), "index beyond dim");
        assert!(parse(Cursor::new("1 1=5\n"), 2).is_err(), "bad pair");
        assert!(parse(Cursor::new("1 1:abc\n"), 2).is_err(), "bad value");
    }

    #[test]
    fn blank_and_comment_lines_are_skipped() {
        // Leading/trailing blank lines, whitespace-only lines, full-line
        // comments, and indented comments all vanish.
        let text = "\n   \n# header comment\n1 1:1\n\t\n  # indented comment\n-1 2:2\n\n";
        let ds = parse(Cursor::new(text), 2).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.get(0).features, &[1.0, 0.0]);
        assert_eq!(ds.get(1).features, &[0.0, 2.0]);
    }

    #[test]
    fn out_of_order_indices_densify_correctly() {
        // libsvm files usually sort indices, but the format does not
        // require it; later pairs win on duplicates.
        let ds = parse(Cursor::new("1 3:3 1:1 2:2\n-1 2:9 2:7\n"), 3).unwrap();
        assert_eq!(ds.get(0).features, &[1.0, 2.0, 3.0]);
        assert_eq!(ds.get(1).features, &[0.0, 7.0, 0.0]);
    }

    #[test]
    fn one_based_index_boundaries() {
        // Index 1 maps to column 0, index dim to the last column.
        let ds = parse(Cursor::new("1 1:5 4:7\n"), 4).unwrap();
        assert_eq!(ds.get(0).features, &[5.0, 0.0, 0.0, 7.0]);
        // Index dim+1 is out of range even though 0-based it would fit.
        assert!(parse(Cursor::new("1 5:1\n"), 4).is_err());
    }

    #[test]
    fn trailing_whitespace_and_crlf_are_tolerated() {
        let ds = parse(Cursor::new("1 1:0.5   \n-1 2:1.5\t\r\n"), 2).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.get(0).features, &[0.5, 0.0]);
        assert_eq!(ds.get(1).features, &[0.0, 1.5]);
        assert_eq!(ds.get(1).label, -1);
    }

    #[test]
    fn labels_may_be_arbitrary_integers() {
        let ds = parse(Cursor::new("3 1:1\n8 2:1\n"), 2).unwrap();
        assert_eq!(ds.labels(), vec![3, 8]);
    }

    #[test]
    fn file_round_trip() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let path = dir.path().join("toy.svm");
        let mut ds = Dataset::new(4);
        ds.push(&[0.0, 1.5, 0.0, -3.0], 1).unwrap();
        ds.push(&[2.0, 0.0, 0.0, 0.0], -1).unwrap();
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, 4).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(0).features, ds.get(0).features);
        assert_eq!(back.get(1).features, ds.get(1).features);
        assert_eq!(back.labels(), ds.labels());
    }
}
