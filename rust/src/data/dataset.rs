//! Dense in-memory dataset types.
//!
//! Examples are stored row-major in one contiguous buffer (cache-friendly
//! for the sequential walker, zero-copy slicing for the runtime's batched
//! literals). Labels are small integers (digit classes 0–9 or ±1 for
//! binary tasks).


use crate::error::{Error, Result};

/// A borrowed view of one example.
#[derive(Debug, Clone, Copy)]
pub struct Example<'a> {
    /// Dense feature vector.
    pub features: &'a [f64],
    /// Class label.
    pub label: i64,
}

/// Dense dataset: `rows × dim` features + one label per row.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    dim: usize,
    features: Vec<f64>,
    labels: Vec<i64>,
}

impl Dataset {
    /// Empty dataset with feature dimensionality `dim`.
    pub fn new(dim: usize) -> Self {
        Self { dim, features: Vec::new(), labels: Vec::new() }
    }

    /// Build from parts. `features.len()` must be a multiple of `dim` and
    /// match `labels.len() * dim`.
    pub fn from_parts(dim: usize, features: Vec<f64>, labels: Vec<i64>) -> Result<Self> {
        if dim == 0 || features.len() != labels.len() * dim {
            return Err(Error::Config(format!(
                "from_parts: dim={dim}, features={}, labels={}",
                features.len(),
                labels.len()
            )));
        }
        Ok(Self { dim, features, labels })
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Append one example.
    pub fn push(&mut self, features: &[f64], label: i64) -> Result<()> {
        if features.len() != self.dim {
            return Err(Error::DimMismatch {
                expected: self.dim,
                got: features.len(),
                context: "Dataset::push".into(),
            });
        }
        self.features.extend_from_slice(features);
        self.labels.push(label);
        Ok(())
    }

    /// Borrow example `i`.
    #[inline]
    pub fn get(&self, i: usize) -> Example<'_> {
        Example { features: &self.features[i * self.dim..(i + 1) * self.dim], label: self.labels[i] }
    }

    /// All labels.
    pub fn labels(&self) -> &[i64] {
        &self.labels
    }

    /// Raw feature buffer (row-major), for the runtime's batched literals.
    pub fn features_raw(&self) -> &[f64] {
        &self.features
    }

    /// Iterate over examples.
    pub fn iter(&self) -> impl Iterator<Item = Example<'_>> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Distinct labels in ascending order.
    pub fn classes(&self) -> Vec<i64> {
        let mut c: Vec<i64> = self.labels.clone();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Count of examples with `label`.
    pub fn class_count(&self, label: i64) -> usize {
        self.labels.iter().filter(|&&l| l == label).count()
    }

    /// Subset by row indices (copies).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let mut out = Dataset::new(self.dim);
        for &i in indices {
            let e = self.get(i);
            out.features.extend_from_slice(e.features);
            out.labels.push(e.label);
        }
        out
    }

    /// Split into (train, test) at `train_fraction` (row order preserved;
    /// shuffle first via [`crate::data::stream`] if needed).
    pub fn split(&self, train_fraction: f64) -> (Dataset, Dataset) {
        let k = ((self.len() as f64) * train_fraction).round() as usize;
        let k = k.min(self.len());
        let train: Vec<usize> = (0..k).collect();
        let test: Vec<usize> = (k..self.len()).collect();
        (self.subset(&train), self.subset(&test))
    }

    /// Normalize features into `[-1, 1]` per the paper's `X_i ∈ [−1,1]`
    /// requirement: affine map from the observed global min/max. No-op on
    /// constant data.
    pub fn normalize_to_unit_range(&mut self) {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &self.features {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !(hi > lo) {
            return;
        }
        let scale = 2.0 / (hi - lo);
        for v in &mut self.features {
            *v = (*v - lo) * scale - 1.0;
        }
    }

    /// Global feature range (diagnostics / invariant checks).
    pub fn feature_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &self.features {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let mut d = Dataset::new(3);
        d.push(&[0.0, 1.0, 2.0], 7).unwrap();
        d.push(&[3.0, 4.0, 5.0], 3).unwrap();
        d.push(&[6.0, 7.0, 8.0], 7).unwrap();
        d
    }

    #[test]
    fn push_get_roundtrip() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 3);
        assert_eq!(d.get(1).features, &[3.0, 4.0, 5.0]);
        assert_eq!(d.get(1).label, 3);
    }

    #[test]
    fn push_rejects_wrong_dim() {
        let mut d = Dataset::new(3);
        assert!(d.push(&[1.0, 2.0], 0).is_err());
    }

    #[test]
    fn from_parts_validates() {
        assert!(Dataset::from_parts(2, vec![1.0; 6], vec![0, 1, 2]).is_ok());
        assert!(Dataset::from_parts(2, vec![1.0; 5], vec![0, 1, 2]).is_err());
        assert!(Dataset::from_parts(0, vec![], vec![]).is_err());
    }

    #[test]
    fn classes_and_counts() {
        let d = toy();
        assert_eq!(d.classes(), vec![3, 7]);
        assert_eq!(d.class_count(7), 2);
        assert_eq!(d.class_count(3), 1);
        assert_eq!(d.class_count(9), 0);
    }

    #[test]
    fn subset_and_split() {
        let d = toy();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(0).features, &[6.0, 7.0, 8.0]);
        let (tr, te) = d.split(2.0 / 3.0);
        assert_eq!(tr.len(), 2);
        assert_eq!(te.len(), 1);
    }

    #[test]
    fn normalization_hits_unit_range() {
        let mut d = toy();
        d.normalize_to_unit_range();
        let (lo, hi) = d.feature_range();
        assert!((lo + 1.0).abs() < 1e-12);
        assert!((hi - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalization_constant_data_noop() {
        let mut d = Dataset::new(2);
        d.push(&[5.0, 5.0], 0).unwrap();
        d.normalize_to_unit_range();
        assert_eq!(d.get(0).features, &[5.0, 5.0]);
    }
}
