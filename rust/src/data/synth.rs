//! Deterministic synthetic digit-glyph generator — the MNIST stand-in.
//!
//! Real MNIST is not bundled (no network at build time); per DESIGN.md §7
//! we substitute a generator that reproduces the *structural properties*
//! the STST's behaviour depends on:
//!
//! * 28×28 grayscale images, many near-zero background pixels (easy mass
//!   for early stopping) and informative stroke pixels;
//! * class-conditional feature variance concentrated on the stroke
//!   regions that differ between digits (what `var_y(x_j)` picks up);
//! * heavy per-sample variation: translation jitter, stroke thickness,
//!   multiplicative stroke noise, and salt noise, so pairs like (3, 8)
//!   are genuinely harder than (2, 3) — matching the paper's 49-vs-72
//!   average-features narrative.
//!
//! Digits are rendered from polyline skeletons on a 28×28 canvas with a
//! soft (Gaussian-falloff) brush. Everything is driven by `ChaCha8Rng`,
//! so a `(seed, count)` pair always yields the identical dataset.

use crate::util::rng::Rng64;

use super::dataset::Dataset;

/// Canvas side; features = SIDE × SIDE = 784, as in MNIST.
pub const SIDE: usize = 28;
/// Feature dimensionality of generated digits.
pub const DIM: usize = SIDE * SIDE;

/// Polyline skeletons for digits 0–9 in a normalized [0,1]² box
/// (x right, y down). Hand-designed to mimic handwritten topology —
/// crucially 3 traces exactly the right half of 8's two lobes (so the
/// hard pair (3,8) differs only on the left arcs), while 2 and 3 differ
/// over larger regions (the easier pair).
fn skeleton(digit: u8) -> &'static [(f32, f32)] {
    match digit {
        0 => &[(0.5, 0.08), (0.22, 0.25), (0.2, 0.7), (0.5, 0.92), (0.78, 0.7), (0.8, 0.25), (0.5, 0.08)],
        1 => &[(0.35, 0.22), (0.55, 0.08), (0.55, 0.92)],
        2 => &[(0.25, 0.28), (0.45, 0.08), (0.72, 0.22), (0.68, 0.45), (0.3, 0.75), (0.22, 0.92), (0.8, 0.9)],
        3 => &[(0.3, 0.12), (0.5, 0.08), (0.72, 0.27), (0.5, 0.47), (0.72, 0.72), (0.5, 0.92), (0.3, 0.88)],
        4 => &[(0.62, 0.92), (0.62, 0.08), (0.2, 0.62), (0.82, 0.62)],
        5 => &[(0.75, 0.1), (0.3, 0.1), (0.27, 0.45), (0.6, 0.42), (0.78, 0.65), (0.6, 0.9), (0.25, 0.85)],
        6 => &[(0.68, 0.1), (0.35, 0.35), (0.25, 0.68), (0.45, 0.9), (0.72, 0.72), (0.55, 0.5), (0.3, 0.62)],
        7 => &[(0.2, 0.1), (0.8, 0.1), (0.5, 0.55), (0.38, 0.92)],
        8 => &[(0.5, 0.08), (0.72, 0.27), (0.5, 0.47), (0.72, 0.72), (0.5, 0.92), (0.28, 0.72), (0.5, 0.47), (0.28, 0.27), (0.5, 0.08)],
        9 => &[(0.72, 0.35), (0.5, 0.08), (0.28, 0.3), (0.5, 0.5), (0.72, 0.35), (0.68, 0.92)],
        _ => panic!("digit must be 0-9, got {digit}"),
    }
}

/// Configuration for the glyph renderer.
#[derive(Debug, Clone, Copy)]
pub struct SynthConfig {
    /// Max translation jitter in pixels (uniform per sample, each axis).
    pub jitter_px: f32,
    /// Brush radius mean (pixels).
    pub stroke_radius: f32,
    /// Brush radius spread (uniform ± around the mean, per sample).
    pub stroke_radius_jitter: f32,
    /// Per-sample global scale jitter (uniform in `1 ± scale_jitter`).
    pub scale_jitter: f32,
    /// Std-dev of additive Gaussian pixel noise (on [0,1] intensities).
    pub pixel_noise: f32,
    /// Probability a background pixel gets salt noise.
    pub salt_prob: f32,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            jitter_px: 2.0,
            stroke_radius: 1.3,
            stroke_radius_jitter: 0.45,
            scale_jitter: 0.12,
            pixel_noise: 0.04,
            salt_prob: 0.01,
        }
    }
}

/// Deterministic synthetic digit generator.
#[derive(Debug, Clone)]
pub struct SynthDigits {
    rng: Rng64,
    cfg: SynthConfig,
    /// Reusable f32 rasterization scratch (see [`Self::render_into`]).
    scratch: Vec<f32>,
}

impl SynthDigits {
    /// Generator with default renderer config.
    pub fn new(seed: u64) -> Self {
        Self::with_config(seed, SynthConfig::default())
    }

    /// Generator with explicit renderer config.
    pub fn with_config(seed: u64, cfg: SynthConfig) -> Self {
        Self { rng: Rng64::seed_from_u64(seed), cfg, scratch: Vec::new() }
    }

    /// Render one digit into a fresh 784-vector of intensities in [0, 1].
    pub fn render(&mut self, digit: u8) -> Vec<f64> {
        let mut out = Vec::new();
        self.render_into(digit, &mut out);
        out
    }

    /// [`Self::render`] into a caller-supplied buffer (cleared and
    /// refilled), reusing the internal rasterization scratch: a render
    /// loop at steady state touches no allocator, which keeps the load
    /// generator off the benchmark's profile. Consumes the identical
    /// RNG stream as [`Self::render`], so traffic is byte-for-byte
    /// reproducible whichever entry point a driver uses.
    pub fn render_into(&mut self, digit: u8, out: &mut Vec<f64>) {
        self.scratch.clear();
        self.scratch.resize(DIM, 0.0f32);
        let mut img = std::mem::take(&mut self.scratch);
        let pts = skeleton(digit);
        let c = self.cfg;

        let dx = self.rng.range_f64(-c.jitter_px as f64, c.jitter_px as f64) as f32;
        let dy = self.rng.range_f64(-c.jitter_px as f64, c.jitter_px as f64) as f32;
        let scale = 1.0 + self.rng.range_f64(-c.scale_jitter as f64, c.scale_jitter as f64) as f32;
        let radius = (c.stroke_radius
            + self.rng.range_f64(-c.stroke_radius_jitter as f64, c.stroke_radius_jitter as f64)
                as f32)
            .max(0.6);
        // mild shear for handwriting slant
        let shear = self.rng.range_f64(-0.15, 0.15) as f32;

        let side = SIDE as f32;
        let map = |p: (f32, f32)| -> (f32, f32) {
            let (mut x, y) = ((p.0 - 0.5) * scale, (p.1 - 0.5) * scale);
            x += shear * y;
            ((x + 0.5) * (side - 6.0) + 3.0 + dx, (y + 0.5) * (side - 6.0) + 3.0 + dy)
        };

        // Rasterize each segment with a soft brush.
        for seg in pts.windows(2) {
            let (x0, y0) = map(seg[0]);
            let (x1, y1) = map(seg[1]);
            let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-3);
            let steps = (len * 3.0).ceil() as usize;
            for s in 0..=steps {
                let t = s as f32 / steps as f32;
                let (cx, cy) = (x0 + t * (x1 - x0), y0 + t * (y1 - y0));
                let r = radius.ceil() as i32 + 1;
                let (icx, icy) = (cx.round() as i32, cy.round() as i32);
                for py in (icy - r).max(0)..=(icy + r).min(SIDE as i32 - 1) {
                    for px in (icx - r).max(0)..=(icx + r).min(SIDE as i32 - 1) {
                        let d2 = (px as f32 - cx).powi(2) + (py as f32 - cy).powi(2);
                        let v = (-d2 / (radius * radius)).exp();
                        let idx = py as usize * SIDE + px as usize;
                        img[idx] = img[idx].max(v);
                    }
                }
            }
        }

        // Pixel noise + salt.
        for v in img.iter_mut() {
            let noise: f32 = self.rng.normal() as f32;
            *v = (*v + c.pixel_noise * noise).clamp(0.0, 1.0);
            if *v < 0.05 && (self.rng.f64() as f32) < c.salt_prob {
                *v = self.rng.range_f64(0.3, 0.9) as f32;
            }
        }

        out.clear();
        out.reserve(DIM);
        out.extend(img.iter().map(|&v| v as f64));
        self.scratch = img;
    }

    /// Generate `count` examples with labels cycling over all ten digits,
    /// already normalized to the paper's `[−1, 1]` feature range.
    pub fn generate(&mut self, count: usize) -> Dataset {
        self.generate_classes(count, &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9])
    }

    /// Generate `count` examples cycling over `classes` only.
    pub fn generate_classes(&mut self, count: usize, classes: &[u8]) -> Dataset {
        assert!(!classes.is_empty());
        let mut ds = Dataset::new(DIM);
        for i in 0..count {
            let digit = classes[i % classes.len()];
            let img = self.render(digit);
            // Intensities stay in [0, 1] ⊂ [−1, 1] (the paper's X_i range):
            // background pixels are exactly 0, so they contribute nothing to
            // the margin — the sparsity structure a bias-free linear model
            // needs (and what real MNIST pixel scaling gives).
            ds.push(&img, digit as i64).expect("dim is fixed");
        }
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = SynthDigits::new(5).generate(20);
        let b = SynthDigits::new(5).generate(20);
        assert_eq!(a.features_raw(), b.features_raw());
        assert_eq!(a.labels(), b.labels());
        let c = SynthDigits::new(6).generate(20);
        assert_ne!(a.features_raw(), c.features_raw());
    }

    #[test]
    fn render_into_matches_render_and_reuses_capacity() {
        // Same seed, two entry points: identical pixels (identical RNG
        // stream), so a driver can switch to the buffered form without
        // changing its traffic.
        let mut a = SynthDigits::new(9);
        let mut b = SynthDigits::new(9);
        let mut buf = Vec::new();
        for digit in [2u8, 3, 7, 2] {
            let fresh = a.render(digit);
            b.render_into(digit, &mut buf);
            assert_eq!(fresh, buf, "digit {digit}");
        }
        // Steady state: neither the out buffer nor the scratch grows.
        let cap = buf.capacity();
        b.render_into(5, &mut buf);
        assert_eq!(buf.capacity(), cap, "render_into must reuse the out buffer");
        assert_eq!(buf.len(), DIM);
    }

    #[test]
    fn features_in_unit_range() {
        let ds = SynthDigits::new(1).generate(30);
        let (lo, hi) = ds.feature_range();
        assert!(lo >= 0.0 && hi <= 1.0, "intensities live in [0,1], got [{lo}, {hi}]");
        assert!(hi > 0.5, "strokes must produce bright pixels, max={hi}");
    }

    #[test]
    fn all_ten_digits_render() {
        let mut g = SynthDigits::new(2);
        for d in 0..10u8 {
            let img = g.render(d);
            let ink: f64 = img.iter().sum();
            assert!(ink > 10.0, "digit {d} rendered almost blank (ink={ink})");
            assert!(ink < (DIM as f64) * 0.6, "digit {d} rendered almost full (ink={ink})");
        }
    }

    #[test]
    fn class_conditional_structure_differs() {
        // Mean image of 2s must differ substantially from mean image of 3s
        // (otherwise no margin signal exists).
        let mut g = SynthDigits::new(3);
        let mean = |digit: u8, g: &mut SynthDigits| -> Vec<f64> {
            let mut acc = vec![0.0; DIM];
            for _ in 0..40 {
                for (a, v) in acc.iter_mut().zip(g.render(digit)) {
                    *a += v / 40.0;
                }
            }
            acc
        };
        let m2 = mean(2, &mut g);
        let m3 = mean(3, &mut g);
        let l1: f64 = m2.iter().zip(&m3).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 > 20.0, "class means nearly identical (l1={l1})");
    }

    #[test]
    fn hard_pair_is_harder_than_easy_pair() {
        // (3,8) mean-image distance should be smaller than (2,3) —
        // the structural reason Fig 4 needs more features than Fig 3.
        let mut g = SynthDigits::new(4);
        let mean = |digit: u8, g: &mut SynthDigits| -> Vec<f64> {
            let mut acc = vec![0.0; DIM];
            for _ in 0..60 {
                for (a, v) in acc.iter_mut().zip(g.render(digit)) {
                    *a += v / 60.0;
                }
            }
            acc
        };
        let m2 = mean(2, &mut g);
        let m3 = mean(3, &mut g);
        let m8 = mean(8, &mut g);
        let d23: f64 = m2.iter().zip(&m3).map(|(a, b)| (a - b) * (a - b)).sum();
        let d38: f64 = m3.iter().zip(&m8).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(d38 < d23, "want (3,8) harder than (2,3): d38={d38:.1} d23={d23:.1}");
    }

    #[test]
    fn generate_classes_cycles_only_requested() {
        let ds = SynthDigits::new(9).generate_classes(11, &[2, 3]);
        assert_eq!(ds.len(), 11);
        assert_eq!(ds.classes(), vec![2, 3]);
        assert_eq!(ds.class_count(2), 6);
        assert_eq!(ds.class_count(3), 5);
    }
}
