//! Dataset substrate.
//!
//! The paper evaluates on MNIST 1-vs-1 digit pairs. This module provides
//! everything needed to reproduce that end-to-end without external
//! downloads:
//!
//! * [`dataset`] — dense in-memory [`dataset::Dataset`] /
//!   [`dataset::Example`] types, normalization to the paper's
//!   `x_i ∈ [−1, 1]` range, summary statistics.
//! * [`synth`] — a deterministic synthetic digit-glyph generator
//!   (28×28 stroke renderer with per-sample jitter, thickness and noise)
//!   standing in for MNIST (see DESIGN.md §7 for why the substitution
//!   preserves the margin structure the STST depends on).
//! * [`mnist`] — an IDX-format reader so *real* MNIST files are used
//!   automatically when present (drop them in `data/mnist/`).
//! * [`task`] — 1-vs-1 binary task extraction ("2 vs 3", "3 vs 8").
//! * [`stream`] — seeded shuffling iterators for online passes.
//! * [`libsvm`] — libsvm/svmlight text I/O for interop.

pub mod dataset;
pub mod libsvm;
pub mod mnist;
pub mod stream;
pub mod synth;
pub mod task;

pub use dataset::{Dataset, Example};
pub use task::BinaryTask;
