//! Binary 1-vs-1 task extraction.
//!
//! The paper's experiments are 1-vs-1 MNIST digit classification: select
//! the examples of two classes, relabel them ±1, and train a binary
//! margin-based learner. [`BinaryTask`] owns the filtered data plus the
//! mapping back to original class labels.


use crate::error::{Error, Result};

use super::dataset::{Dataset, Example};

/// A binary classification task extracted from a multiclass dataset.
#[derive(Debug, Clone)]
pub struct BinaryTask {
    /// Original class mapped to +1.
    pub positive_class: i64,
    /// Original class mapped to −1.
    pub negative_class: i64,
    data: Dataset,
    labels: Vec<f64>,
}

impl BinaryTask {
    /// Extract the examples of `positive` and `negative` from `ds` and
    /// relabel them +1 / −1 (row order preserved).
    pub fn one_vs_one(ds: &Dataset, positive: i64, negative: i64) -> Result<Self> {
        if positive == negative {
            return Err(Error::Config(format!("1-vs-1 with identical classes {positive}")));
        }
        let idx: Vec<usize> = (0..ds.len())
            .filter(|&i| {
                let l = ds.get(i).label;
                l == positive || l == negative
            })
            .collect();
        if idx.is_empty() {
            return Err(Error::UnknownClass(positive));
        }
        let data = ds.subset(&idx);
        let labels: Vec<f64> =
            data.labels().iter().map(|&l| if l == positive { 1.0 } else { -1.0 }).collect();
        if !labels.iter().any(|&y| y > 0.0) {
            return Err(Error::UnknownClass(positive));
        }
        if !labels.iter().any(|&y| y < 0.0) {
            return Err(Error::UnknownClass(negative));
        }
        Ok(Self { positive_class: positive, negative_class: negative, data, labels })
    }

    /// Build directly from a dataset already labeled ±1.
    pub fn from_signed(data: Dataset) -> Result<Self> {
        let labels: Vec<f64> = data
            .labels()
            .iter()
            .map(|&l| match l {
                1 => Ok(1.0),
                -1 => Ok(-1.0),
                other => Err(Error::UnknownClass(other)),
            })
            .collect::<Result<_>>()?;
        Ok(Self { positive_class: 1, negative_class: -1, data, labels })
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.data.dim()
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the task empty?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Example `i` with its ±1 label.
    #[inline]
    pub fn get(&self, i: usize) -> (Example<'_>, f64) {
        (self.data.get(i), self.labels[i])
    }

    /// Signed labels (±1), one per example.
    pub fn signed_labels(&self) -> &[f64] {
        &self.labels
    }

    /// Underlying (filtered) dataset.
    pub fn data(&self) -> &Dataset {
        &self.data
    }

    /// Task name like `"2v3"` for reports.
    pub fn name(&self) -> String {
        format!("{}v{}", self.positive_class, self.negative_class)
    }

    /// Split into (train, test). Row order preserved; shuffle upstream.
    pub fn split(&self, train_fraction: f64) -> (BinaryTask, BinaryTask) {
        let k = ((self.len() as f64) * train_fraction).round() as usize;
        let k = k.min(self.len());
        let idx_tr: Vec<usize> = (0..k).collect();
        let idx_te: Vec<usize> = (k..self.len()).collect();
        (self.reindex(&idx_tr), self.reindex(&idx_te))
    }

    /// Reorder/subset by indices.
    pub fn reindex(&self, indices: &[usize]) -> BinaryTask {
        BinaryTask {
            positive_class: self.positive_class,
            negative_class: self.negative_class,
            data: self.data.subset(indices),
            labels: indices.iter().map(|&i| self.labels[i]).collect(),
        }
    }

    /// Fraction of positive examples (class balance diagnostic).
    pub fn positive_fraction(&self) -> f64 {
        if self.labels.is_empty() {
            return 0.0;
        }
        self.labels.iter().filter(|&&y| y > 0.0).count() as f64 / self.labels.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn multiclass() -> Dataset {
        let mut d = Dataset::new(2);
        for (f, l) in [
            ([0.0, 0.1], 2),
            ([1.0, 1.1], 3),
            ([2.0, 2.1], 5),
            ([3.0, 3.1], 2),
            ([4.0, 4.1], 3),
        ] {
            d.push(&f, l).unwrap();
        }
        d
    }

    #[test]
    fn one_vs_one_filters_and_relabels() {
        let t = BinaryTask::one_vs_one(&multiclass(), 2, 3).unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.signed_labels(), &[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(t.get(0).0.features, &[0.0, 0.1]);
        assert_eq!(t.name(), "2v3");
        assert!((t.positive_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn one_vs_one_rejects_missing_class() {
        assert!(BinaryTask::one_vs_one(&multiclass(), 2, 9).is_err());
        assert!(BinaryTask::one_vs_one(&multiclass(), 9, 8).is_err());
        assert!(BinaryTask::one_vs_one(&multiclass(), 2, 2).is_err());
    }

    #[test]
    fn from_signed_validates_labels() {
        let mut d = Dataset::new(1);
        d.push(&[0.5], 1).unwrap();
        d.push(&[0.6], -1).unwrap();
        let t = BinaryTask::from_signed(d).unwrap();
        assert_eq!(t.signed_labels(), &[1.0, -1.0]);

        let mut bad = Dataset::new(1);
        bad.push(&[0.5], 2).unwrap();
        assert!(BinaryTask::from_signed(bad).is_err());
    }

    #[test]
    fn split_and_reindex() {
        let t = BinaryTask::one_vs_one(&multiclass(), 2, 3).unwrap();
        let (tr, te) = t.split(0.5);
        assert_eq!(tr.len(), 2);
        assert_eq!(te.len(), 2);
        let r = t.reindex(&[3, 0]);
        assert_eq!(r.signed_labels(), &[-1.0, 1.0]);
    }
}
