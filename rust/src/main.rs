//! `attentive` — CLI launcher for the Stochastic Focus of Attention stack.
//!
//! Subcommands:
//! * `train`       — run one experiment config (or the paper default) and
//!   print the Figure-3-style summary row.
//! * `sweep`       — run every `*.json` config in a directory.
//! * `simulate`    — Figure 2 boundary validation (decision errors +
//!   stopping times).
//! * `serve`       — serve early-stopped predictions: either over TCP
//!   (`--listen ADDR`, JSON-lines protocol with stats + hot reload;
//!   `--model name=path`, repeatable, hosts a registry of named shards —
//!   binary models and all-pairs ensembles — behind the one port) or
//!   in-process over synthetic traffic (throughput/feature stats).
//! * `train-multiclass` — train the all-pairs 1-vs-1 attentive ensemble
//!   on synthetic digits and write its serving snapshot.
//! * `bench-serve` — drive a serving front-end over loopback with the
//!   load-generator client and compare attentive vs full evaluation.
//! * `init-config` — write a default config to edit.
//! * `export-idx`  — snapshot the synthetic digit set as MNIST IDX files.

use std::path::PathBuf;

use anyhow::{bail, Context};

use attentive::config::{BrownoutConfig, ExperimentConfig, ServerConfig, TrainerWireConfig};
use attentive::coordinator::scheduler::{run_experiment, run_sweep};
use attentive::coordinator::service::{
    EnsembleSnapshot, ModelSnapshot, PredictionService, ServingModel,
};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::stream::ShuffledIndices;
use attentive::data::synth::SynthDigits;
use attentive::learner::multiclass::OneVsOneEnsemble;
use attentive::learner::pegasos::PegasosConfig;
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::{curves_to_csv, Table};
use attentive::server::loadgen::{self, Client, ClientMode, LoadGenConfig};
use attentive::server::registry::DEFAULT_MODEL;
use attentive::server::tcp::TcpServer;
use attentive::sim::bridge::{simulate_decision_errors, BridgeSimConfig};
use attentive::sim::stopping::{fit_sqrt, simulate_stopping_times, StoppingSimConfig};
use attentive::stst::boundary::AnyBoundary;
use attentive::util::cli::Args;
use attentive::util::json::Json;

const USAGE: &str = "\
attentive — Rapid Learning with Stochastic Focus of Attention (ICML 2011)

USAGE: attentive <COMMAND> [OPTIONS]

COMMANDS:
  train        [--config exp.json] [--csv out.csv]
  train-multiclass
               [--classes 1,2,3] [--count N] [--epochs E] [--lambda L]
               [--delta D] [--seed S] [--out ensemble.json]
               trains the all-pairs 1-vs-1 attentive ensemble on synthetic
               digits and writes its serving snapshot (host it with
               serve --model digits=ensemble.json; score it with classify)
  sweep        <dir> [--csv out.csv]
  simulate     [--walks N] [--csv out.csv]
  serve        [--listen ADDR] [--snapshot model.json] [--server-config srv.json]
               [--model name=path ...] [--requests N] [--batch B]
               [--workers W] [--queue Q] [--max-batch-examples N]
               [--io-backend threads|event-loop] [--event-threads T]
               [--max-conns N] [--learn] [--learn-queue N]
               [--learn-publish-updates K] [--learn-publish-ms T]
               [--learn-lambda L] [--learn-seed S]
               [--snapshot-dir DIR] [--write-timeout-ms T]
               [--idle-timeout-ms T] [--deadline-default-ms T]
               [--brownout] [--brownout-tighten F] [--brownout-enter F]
               [--brownout-exit F] [--brownout-dwell-ms T]
               [--brownout-sample-ms T] [--brownout-latency-us U]
               with --listen: TCP server (v1 JSON lines; a hello op with
               proto 2..7 upgrades a connection to binary frames —
               docs/PROTOCOL.md). --model name=path (repeatable) serves a
               registry of named shards behind one port: each path holds a
               binary ModelSnapshot or an ensemble snapshot, the first name
               is the default shard, and every shard hot-reloads
               independently. Under protocol v5 the add-model and
               remove-model ops grow and shrink the shard set at runtime
               without restarting (docs/OPERATIONS.md); protocol v6 adds
               batched scoring (SCORE_BATCH frames / the score-batch op,
               up to --max-batch-examples examples per request costing one
               queue slot). --io-backend event-loop multiplexes all
               connections over T epoll threads (the default on Linux;
               thousands of idle connections) instead of a thread pair per
               connection; threads is the portable fallback.
               --learn attaches an online trainer to every binary shard:
               the learn op streams labeled examples into a per-shard
               background Attentive Pegasos that republishes the serving
               snapshot every K updates and/or T ms.
               --snapshot-dir DIR makes training crash-safe: every
               published generation is persisted atomically under
               DIR/<shard>/ and a restarted server recovers each shard
               from its newest valid snapshot (torn files are skipped).
               --write-timeout-ms bounds slow-reader writes (default
               2000, 0 = never); --idle-timeout-ms reaps connections
               with no traffic and no pending work (default 0 = never).
               protocol v7 adds overload robustness: requests may carry
               a relative deadline (deadline_ms / the EX frames) and an
               admission lane (interactive|bulk) — an expired request is
               answered with the retryable deadline-exceeded error at
               dequeue instead of being scored; --deadline-default-ms
               stamps a default on requests that carry none (0 = off).
               --brownout arms graceful degradation: a controller
               samples queue occupancy (and optionally latency vs
               --brownout-latency-us) every --brownout-sample-ms and
               walks tiers normal → brown-1 → brown-2 → shed, each brown
               tier tightening the early-exit thresholds by
               --brownout-tighten (responses flag degraded: true; tier 3
               sheds bulk-lane admissions); enter/exit occupancy
               fractions and --brownout-dwell-ms set the hysteresis
               (docs/OPERATIONS.md).
               otherwise: in-process synthetic benchmark
  bench-serve  [--addr ADDR]
               [--mode v1-dense|v2-sparse-json|v2-binary|batch|classify|learn|mixed]
               [--model NAME] [--requests N] [--connections C] [--pipeline P]
               [--hard FRAC] [--sparse-eps E] [--batch B] [--workers W]
               [--queue Q] [--batch-examples N]
               [--io-backend threads|event-loop]
               [--event-threads T] [--open-loop] [--churn N]
               [--retries N] [--deadline-ms T]
               [--json BENCH_serve.json] [--floors ci/bench_floors.json]
               without --addr: spawns a loopback server and compares the
               three wire modes, a batched SCORE_BATCH pass
               (--batch-examples per frame, tallied per example so its
               req/s divides by the v2-binary singles pass directly), a
               multiclass classify pass, online
               learn + mixed learn/score passes against a dedicated
               trainer-backed shard, and full evaluation on the same
               traffic; --io-backend selects the loopback server's
               transport; --open-loop sweeps one request at a time
               across C mostly-idle connections (the many-connections
               scaling check) instead of pipelining; --churn N runs N
               add-model → score → remove-model cycles on throwaway
               shards alongside each pass (registry churn under load);
               --retries N arms per-connection fault recovery: a driver
               whose socket dies reconnects and re-sends its unanswered
               window, up to N consecutive times before giving up
               (progress refreshes the budget; default 0 = fail fast);
               --deadline-ms T stamps a relative deadline on every
               binary score request (v7 EX frames; requests expired in
               queue are shed with the retryable deadline-exceeded
               error and tallied, never silently dropped);
               --json writes the machine-readable report, --floors gates
               on committed throughput floors (exit 1 on regression)
  init-config  [out.json]
  export-idx   <dir> [--count N] [--seed S]
  help
";

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse_with(&argv[1..], &["open-loop", "learn", "brownout"])
        .map_err(|e| anyhow::anyhow!(e))?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "train-multiclass" => cmd_train_multiclass(&args),
        "sweep" => cmd_sweep(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "init-config" => {
            let cfg = ExperimentConfig::paper_default();
            let text = cfg.to_json().to_string_pretty();
            match args.pos(0) {
                Some(p) => {
                    std::fs::write(p, text)?;
                    println!("wrote {p}");
                }
                None => println!("{text}"),
            }
            Ok(())
        }
        "export-idx" => {
            let dir = PathBuf::from(args.pos(0).context("export-idx needs a directory")?);
            let count = args.get_parse("count", 10_000usize).map_err(|e| anyhow::anyhow!(e))?;
            let seed = args.get_parse("seed", 7u64).map_err(|e| anyhow::anyhow!(e))?;
            std::fs::create_dir_all(&dir)?;
            let ds = SynthDigits::new(seed).generate(count);
            attentive::data::mnist::write_idx_pair(
                &ds,
                28,
                &dir.join("train-images-idx3-ubyte"),
                &dir.join("train-labels-idx1-ubyte"),
            )?;
            println!("wrote {count} examples to {}", dir.display());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = match args.opt("config") {
        Some(p) => ExperimentConfig::load(std::path::Path::new(p)).context("loading config")?,
        None => ExperimentConfig::paper_default(),
    };
    let dim_hint = 784usize;
    let out = run_experiment(&cfg)?;
    let mut table = Table::new(&[
        "experiment",
        "learner",
        "avg feats/ex",
        "speedup",
        "test err (full)",
        "test err (early)",
        "pred feats",
    ]);
    table.row(&[
        out.name.clone(),
        out.learner.clone(),
        format!("{:.1}", out.avg_features),
        format!("{:.1}x", out.speedup(dim_hint)),
        format!("{:.4}", out.final_test_error),
        format!("{:.4}", out.final_test_error_early),
        format!("{:.1}", out.predict_avg_features),
    ]);
    println!("{}", table.render());
    if let Some(p) = args.opt("csv") {
        let p = PathBuf::from(p);
        curves_to_csv(&[out.mean_features.clone(), out.mean_test_error.clone()], &p)?;
        println!("curves written to {}", p.display());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.pos(0).context("sweep needs a config directory")?);
    let mut configs = Vec::new();
    for entry in std::fs::read_dir(&dir).context("reading sweep dir")? {
        let path = entry?.path();
        if path.extension().is_some_and(|e| e == "json") {
            configs.push(ExperimentConfig::load(&path)?);
        }
    }
    configs.sort_by(|a, b| a.name.cmp(&b.name));
    if configs.is_empty() {
        bail!("no *.json configs in {}", dir.display());
    }
    let outcomes = run_sweep(&configs)?;
    let mut table = Table::new(&[
        "experiment",
        "learner",
        "avg feats/ex",
        "test err (full)",
        "test err (early)",
    ]);
    let mut curves = Vec::new();
    for out in &outcomes {
        table.row(&[
            out.name.clone(),
            out.learner.clone(),
            format!("{:.1}", out.avg_features),
            format!("{:.4}", out.final_test_error),
            format!("{:.4}", out.final_test_error_early),
        ]);
        curves.push(out.mean_features.clone());
        curves.push(out.mean_test_error.clone());
    }
    println!("{}", table.render());
    if let Some(p) = args.opt("csv") {
        curves_to_csv(&curves, &PathBuf::from(p))?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let walks = args.get_parse("walks", 20_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let cfg = BridgeSimConfig { walks_per_cell: walks, ..Default::default() };
    let ns = [256usize, 1024, 4096];
    let deltas = [0.01, 0.05, 0.1, 0.2, 0.3];
    let pts = simulate_decision_errors(&cfg, &ns, &deltas);
    let mut table =
        Table::new(&["n", "delta (target)", "empirical err", "stop rate", "mean stop t"]);
    for p in &pts {
        table.row(&[
            p.n.to_string(),
            format!("{:.3}", p.delta),
            format!("{:.4}", p.empirical),
            format!("{:.3}", p.stop_rate),
            format!("{:.1}", p.mean_stop_time),
        ]);
    }
    println!("Figure 2(a) — decision errors vs theory\n{}", table.render());

    let scfg = StoppingSimConfig::default();
    let ns2 = [64usize, 128, 256, 512, 1024, 2048, 4096];
    let spts = simulate_stopping_times(&scfg, &ns2);
    let (c, r2) = fit_sqrt(&spts);
    let mut t2 = Table::new(&["n", "mean stop", "std", "wald bound"]);
    for p in &spts {
        t2.row(&[
            p.n.to_string(),
            format!("{:.1}", p.mean_stop),
            format!("{:.1}", p.std_stop),
            format!("{:.1}", p.wald_bound),
        ]);
    }
    println!(
        "Figure 2(b) — stopping times (fit: E[T] ≈ {c:.2}·sqrt(n), R² = {r2:.4})\n{}",
        t2.render()
    );
    if let Some(p) = args.opt("csv") {
        use attentive::metrics::curve::Curve;
        let mut err = Curve::new("fig2a/empirical-error");
        for q in &pts {
            err.push(q.n as f64 * 1000.0 + q.delta, q.empirical);
        }
        let mut stop = Curve::new("fig2b/mean-stop");
        for q in &spts {
            stop.push(q.n as f64, q.mean_stop);
        }
        curves_to_csv(&[err, stop], &PathBuf::from(p))?;
    }
    Ok(())
}

/// Train the all-pairs 1-vs-1 attentive ensemble on synthetic digits
/// and write its serving snapshot.
fn cmd_train_multiclass(args: &Args) -> anyhow::Result<()> {
    let mut classes: Vec<i64> = args
        .get("classes", "1,2,3")
        .split(',')
        .map(|s| s.trim().parse::<i64>().map_err(|_| anyhow::anyhow!("bad class {s:?}")))
        .collect::<anyhow::Result<_>>()?;
    // Dedup before the count check: OneVsOneEnsemble dedups internally,
    // so "--classes 3,3" would otherwise slip through as a degenerate
    // 1-class / 0-voter ensemble that serve later refuses to load.
    classes.sort_unstable();
    classes.dedup();
    if classes.len() < 2 {
        bail!("train-multiclass needs at least 2 distinct classes");
    }
    for &c in &classes {
        if !(0..=9).contains(&c) {
            bail!("synthetic digit classes must be 0..=9, got {c}");
        }
    }
    let count = args.get_parse("count", 3_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let epochs = args.get_parse("epochs", 2u64).map_err(|e| anyhow::anyhow!(e))?;
    let lambda = args.get_parse("lambda", 1e-2f64).map_err(|e| anyhow::anyhow!(e))?;
    let delta = args.get_parse("delta", 0.1f64).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_parse("seed", 7u64).map_err(|e| anyhow::anyhow!(e))?;

    let digit_classes: Vec<u8> = classes.iter().map(|&c| c as u8).collect();
    let ds = SynthDigits::new(seed).generate_classes(count, &digit_classes);
    let (train, test) = ds.split(0.8);
    let boundary = AnyBoundary::Constant { delta, paper_literal: false };
    let cfg = PegasosConfig { lambda, seed, ..Default::default() };
    let mut ensemble = OneVsOneEnsemble::new(train.dim(), &classes, cfg, boundary.clone())?;
    let shuffle = ShuffledIndices::new(train.len(), seed);
    let mut spent = 0u64;
    for epoch in 0..epochs {
        spent += ensemble.train_pass(&train, &shuffle.epoch(epoch));
    }
    let (acc, pred_features) = ensemble.evaluate(&test);
    let per_example = spent as f64 / (train.len() as f64 * epochs as f64);
    println!(
        "{} classes, {} voters: accuracy {:.4}, train features/example {:.1}, \
         predict features/example {:.1} (dim {}, {} voters consulted each)",
        classes.len(),
        ensemble.voter_count(),
        acc,
        per_example,
        pred_features,
        train.dim(),
        ensemble.voter_count(),
    );
    // Permuted prediction order: pixel order is spatially correlated,
    // violating the bridge's exchangeability assumption (see DESIGN.md).
    let snapshot =
        EnsembleSnapshot::from_trained(&mut ensemble, boundary, CoordinatePolicy::Permuted);
    let text = snapshot.to_json().to_string_pretty();
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, text)?;
            println!("ensemble snapshot written to {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// Train a small all-pairs ensemble for the bench-serve classify pass
/// (three classes → three voters; enough to show the per-voter
/// attention compounding at CI scale).
fn train_quick_ensemble() -> anyhow::Result<EnsembleSnapshot> {
    let classes = [1i64, 2, 3];
    let ds = SynthDigits::new(13).generate_classes(2_000, &[1, 2, 3]);
    let boundary = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
    let cfg = PegasosConfig { lambda: 1e-2, seed: 13, ..Default::default() };
    let mut ensemble = OneVsOneEnsemble::new(ds.dim(), &classes, cfg, boundary.clone())?;
    let shuffle = ShuffledIndices::new(ds.len(), 13);
    for epoch in 0..2 {
        ensemble.train_pass(&ds, &shuffle.epoch(epoch));
    }
    Ok(EnsembleSnapshot::from_trained(&mut ensemble, boundary, CoordinatePolicy::Permuted))
}

/// Train a quick attentive snapshot from the paper-default experiment
/// (used whenever the serve commands are not given `--snapshot`).
fn train_default_snapshot() -> anyhow::Result<ModelSnapshot> {
    let cfg = ExperimentConfig::paper_default();
    let (train, _) = attentive::coordinator::factory::build_task(&cfg)?;
    let mut learner =
        attentive::learner::attentive::attentive_pegasos(train.dim(), cfg.lambda, 0.1);
    Trainer::new(TrainerConfig { curves: false, eval_every: 0, ..Default::default() })
        .fit(&mut learner, &train);
    Ok(ModelSnapshot::from_trained(
        &mut learner,
        attentive::stst::boundary::AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        // Permuted: pixel order is spatially correlated, violating the
        // bridge's exchangeability assumption (see DESIGN.md §4).
        attentive::margin::policy::CoordinatePolicy::Permuted,
    ))
}

/// `--snapshot model.json` if given, otherwise train the default model.
fn load_or_train_snapshot(args: &Args) -> anyhow::Result<ModelSnapshot> {
    match args.opt("snapshot") {
        Some(path) => {
            let text = std::fs::read_to_string(path).context("reading snapshot")?;
            let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("snapshot json: {e}"))?;
            ModelSnapshot::from_json(&doc).map_err(|e| anyhow::anyhow!("snapshot: {e}"))
        }
        None => {
            eprintln!("no --snapshot given; training the paper-default attentive model ...");
            train_default_snapshot()
        }
    }
}

/// Resolve the server knobs: `--server-config` file first, then
/// individual flag overrides.
fn server_config_from_args(args: &Args) -> anyhow::Result<ServerConfig> {
    let mut cfg = match args.opt("server-config") {
        Some(p) => ServerConfig::load(std::path::Path::new(p)).context("loading server config")?,
        None => ServerConfig::default(),
    };
    if let Some(listen) = args.opt("listen") {
        cfg.listen = listen.to_string();
    }
    cfg.max_batch = args.get_parse("batch", cfg.max_batch).map_err(|e| anyhow::anyhow!(e))?;
    cfg.workers = args.get_parse("workers", cfg.workers).map_err(|e| anyhow::anyhow!(e))?;
    cfg.queue = args.get_parse("queue", cfg.queue).map_err(|e| anyhow::anyhow!(e))?;
    cfg.max_batch_examples = args
        .get_parse("max-batch-examples", cfg.max_batch_examples)
        .map_err(|e| anyhow::anyhow!(e))?;
    if let Some(backend) = args.opt("io-backend") {
        cfg.io_backend =
            attentive::config::IoBackend::from_name(backend).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.event_threads =
        args.get_parse("event-threads", cfg.event_threads).map_err(|e| anyhow::anyhow!(e))?;
    cfg.max_conns =
        args.get_parse("max-conns", cfg.max_conns).map_err(|e| anyhow::anyhow!(e))?;
    cfg.write_timeout_ms = args
        .get_parse("write-timeout-ms", cfg.write_timeout_ms)
        .map_err(|e| anyhow::anyhow!(e))?;
    cfg.idle_timeout_ms =
        args.get_parse("idle-timeout-ms", cfg.idle_timeout_ms).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(dir) = args.opt("snapshot-dir") {
        cfg.snapshot_dir = Some(std::path::PathBuf::from(dir));
    }
    cfg.deadline_default_ms = args
        .get_parse("deadline-default-ms", cfg.deadline_default_ms)
        .map_err(|e| anyhow::anyhow!(e))?;
    // `--brownout` arms the degradation controller with its defaults;
    // the `--brownout-*` knobs also tune a brownout block that came in
    // via `--server-config`.
    if args.has("brownout") && cfg.brownout.is_none() {
        cfg.brownout = Some(BrownoutConfig::default());
    }
    if let Some(b) = &mut cfg.brownout {
        b.tighten =
            args.get_parse("brownout-tighten", b.tighten).map_err(|e| anyhow::anyhow!(e))?;
        b.enter = args.get_parse("brownout-enter", b.enter).map_err(|e| anyhow::anyhow!(e))?;
        b.exit = args.get_parse("brownout-exit", b.exit).map_err(|e| anyhow::anyhow!(e))?;
        b.dwell_ms =
            args.get_parse("brownout-dwell-ms", b.dwell_ms).map_err(|e| anyhow::anyhow!(e))?;
        b.sample_ms =
            args.get_parse("brownout-sample-ms", b.sample_ms).map_err(|e| anyhow::anyhow!(e))?;
        b.latency_target_us = args
            .get_parse("brownout-latency-us", b.latency_target_us)
            .map_err(|e| anyhow::anyhow!(e))?;
        b.validate().map_err(|e| anyhow::anyhow!("--brownout: {e}"))?;
    }
    // `--learn` attaches an online trainer to every binary shard (the
    // `learn` op); the `--learn-*` knobs also tune a trainer block that
    // came in via `--server-config`.
    if args.has("learn") && cfg.trainer.is_none() {
        cfg.trainer = Some(TrainerWireConfig::default());
    }
    if let Some(t) = &mut cfg.trainer {
        t.queue = args.get_parse("learn-queue", t.queue).map_err(|e| anyhow::anyhow!(e))?;
        t.publish_every_updates = args
            .get_parse("learn-publish-updates", t.publish_every_updates)
            .map_err(|e| anyhow::anyhow!(e))?;
        t.publish_every_ms = args
            .get_parse("learn-publish-ms", t.publish_every_ms)
            .map_err(|e| anyhow::anyhow!(e))?;
        t.lambda = args.get_parse("learn-lambda", t.lambda).map_err(|e| anyhow::anyhow!(e))?;
        t.seed = args.get_parse("learn-seed", t.seed).map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok(cfg)
}

/// Parse the repeatable `--model name=path` flags into registry shards.
fn parse_model_flags(args: &Args) -> anyhow::Result<Vec<(String, ServingModel)>> {
    let mut models = Vec::new();
    for spec in args.opt_all("model") {
        let (name, path) = spec
            .split_once('=')
            .with_context(|| format!("--model {spec:?}: expected name=path"))?;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model {name:?} from {path}"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("model {name:?}: {e}"))?;
        let model =
            ServingModel::from_json(&doc).map_err(|e| anyhow::anyhow!("model {name:?}: {e}"))?;
        models.push((name.to_string(), model));
    }
    Ok(models)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let model_flags = parse_model_flags(args)?;
    if !model_flags.is_empty() && args.opt("snapshot").is_some() {
        // Refuse the ambiguity rather than silently ignoring one flag:
        // with --model the default shard is the first --model entry.
        bail!(
            "--snapshot and --model are mutually exclusive; list the default shard first, \
             e.g. --model default={}",
            args.opt("snapshot").unwrap_or("model.json")
        );
    }
    if args.opt("listen").is_some()
        || args.opt("server-config").is_some()
        || !model_flags.is_empty()
    {
        // Network mode: TCP front-end with hot reload, hosting either
        // one default shard (--snapshot / trained on the fly) or the
        // full --model registry.
        let cfg = server_config_from_args(args)?;
        let models = if model_flags.is_empty() {
            vec![(DEFAULT_MODEL.to_string(), load_or_train_snapshot(args)?.into())]
        } else {
            model_flags
        };
        let summary: Vec<String> = models
            .iter()
            .map(|(name, m)| {
                if m.voter_count() > 0 {
                    format!("{name}=ensemble(dim {}, {} voters)", m.dim(), m.voter_count())
                } else {
                    format!("{name}=binary(dim {})", m.dim())
                }
            })
            .collect();
        let server = TcpServer::serve_models(&cfg, models)?;
        println!(
            "serving {} shard(s) on {} ({} backend, {} workers/shard, batch {}, queue {}): {}",
            summary.len(),
            server.local_addr(),
            cfg.io_backend.name(),
            cfg.workers,
            cfg.max_batch,
            cfg.queue,
            summary.join(", ")
        );
        println!(
            "ops: score / classify / stats / models / reload / add-model / remove-model / \
             ping / hello — one JSON object per line; optional \"model\" field routes to a \
             named shard"
        );
        println!(
            "protocol v2-v7: hello {{\"proto\":7}} switches to sparse binary frames; v6 adds \
             batched scoring (SCORE_BATCH frames / the score-batch op, up to {} examples per \
             request); v7 adds per-request deadlines and admission lanes (the EX frames / the \
             deadline_ms and priority fields)",
            cfg.max_batch_examples
        );
        if let Some(b) = &cfg.brownout {
            println!(
                "brownout on: tiers tighten early-exit thresholds by {} per step \
                 (enter {:.2} / exit {:.2} occupancy, dwell {} ms, sample {} ms{}); \
                 degraded responses are flagged, tier 3 sheds bulk-lane admissions",
                b.tighten,
                b.enter,
                b.exit,
                b.dwell_ms,
                b.sample_ms,
                if b.latency_target_us > 0 {
                    format!(", latency target {} us", b.latency_target_us)
                } else {
                    String::new()
                }
            );
        }
        if cfg.deadline_default_ms > 0 {
            println!(
                "default deadline: {} ms stamped on requests that carry none",
                cfg.deadline_default_ms
            );
        }
        if cfg.trainer.is_some() {
            println!(
                "online learning on: the learn op (JSON, or LEARN_SPARSE frames under \
                 protocol v4) streams labeled examples into each binary shard's trainer"
            );
        }
        if let Some(dir) = &cfg.snapshot_dir {
            println!(
                "snapshot persistence on: published generations land in {}/<shard>/ and \
                 the newest valid one is recovered on restart",
                dir.display()
            );
        }
        server.wait();
        return Ok(());
    }

    // In-process mode: serve synthetic traffic and print stats.
    let requests = args.get_parse("requests", 2_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let batch = args.get_parse("batch", 16usize).map_err(|e| anyhow::anyhow!(e))?;
    let workers = args.get_parse("workers", 2usize).map_err(|e| anyhow::anyhow!(e))?;
    let snapshot = load_or_train_snapshot(args)?;

    let (handle, run) =
        PredictionService::new(snapshot, batch, 1024, 0).with_workers(workers).spawn();
    let t0 = std::time::Instant::now();
    // Client threads generate digit traffic and block on responses.
    let clients = 8usize;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let handle = handle.clone();
            let per_client = requests / clients;
            scope.spawn(move || {
                let mut gen = SynthDigits::new(99 + c as u64);
                for i in 0..per_client {
                    let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
                    let img: Vec<f64> = gen.render(digit);
                    let _ = handle.score(img);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let s = run.stats.snapshot();
    drop(handle);
    run.join();
    println!(
        "served {} requests in {:.3}s ({:.0} req/s), avg features/prediction {:.1} of 784, batches {}",
        s.served,
        dt,
        s.served as f64 / dt,
        s.avg_features(),
        s.batches
    );
    Ok(())
}

/// Gate a bench report against committed floors (`ci/bench_floors.json`):
/// a missing floor key simply does not gate. Returns the violations.
fn check_bench_floors(report: &Json, floors: &Json) -> Vec<String> {
    let mut violations = Vec::new();
    let ratio = report.get("ratio_v2_binary_vs_v1_dense").and_then(|x| x.as_f64());
    if let Some(min_ratio) =
        floors.get("v2_binary_vs_v1_dense_min_ratio").and_then(|x| x.as_f64())
    {
        match ratio {
            Some(r) if r >= min_ratio => {}
            Some(r) => violations.push(format!(
                "v2-binary is only {r:.2}x v1-dense throughput (floor {min_ratio:.2}x)"
            )),
            None => violations.push("report lacks ratio_v2_binary_vs_v1_dense".into()),
        }
    }
    if let Some(min_ratio) =
        floors.get("v2_sparse_json_vs_v1_dense_min_ratio").and_then(|x| x.as_f64())
    {
        match report.get("ratio_v2_sparse_json_vs_v1_dense").and_then(|x| x.as_f64()) {
            Some(r) if r >= min_ratio => {}
            Some(r) => violations.push(format!(
                "v2-sparse-json is only {r:.2}x v1-dense throughput (floor {min_ratio:.2}x)"
            )),
            None => violations.push("report lacks ratio_v2_sparse_json_vs_v1_dense".into()),
        }
    }
    // The batched-scoring payoff gate: batch and singles passes both
    // tally per example, so their req/s ratio is the speedup SCORE_BATCH
    // buys over single v2-binary frames on identical traffic.
    if let Some(min_ratio) = floors.get("batch_vs_singles_min_ratio").and_then(|x| x.as_f64()) {
        match report.get("ratio_batch_vs_singles").and_then(|x| x.as_f64()) {
            Some(r) if r >= min_ratio => {}
            Some(r) => violations.push(format!(
                "batched scoring is only {r:.2}x v2-binary singles throughput \
                 (floor {min_ratio:.2}x)"
            )),
            None => violations.push("report lacks ratio_batch_vs_singles".into()),
        }
    }
    // Per-mode absolute floors, generically: any floors key of the form
    // `<mode>_min_req_per_s` (underscores standing for the dashes in
    // the mode name) gates that mode's throughput. A key prefixed
    // `event_loop_` applies only to reports stamped with that backend,
    // so the event loop can carry its own floor next to the shared
    // ones.
    let backend = report.get("io_backend").and_then(|s| s.as_str()).unwrap_or("threads");
    if let Json::Obj(pairs) = floors {
        for (key, value) in pairs {
            let Some(rest) = key.strip_suffix("_min_req_per_s") else { continue };
            let Some(min_rps) = value.as_f64() else { continue };
            let (applies, mode_key) = match rest.strip_prefix("event_loop_") {
                Some(mode) => (backend == "event-loop", mode),
                None => (true, rest),
            };
            if !applies {
                continue;
            }
            let mode_name = mode_key.replace('_', "-");
            let rps = report
                .get("modes")
                .and_then(|m| m.get(&mode_name))
                .and_then(|m| m.get("req_per_s"))
                .and_then(|x| x.as_f64());
            match rps {
                Some(r) if r >= min_rps => {}
                Some(r) => violations.push(format!(
                    "{mode_name} {r:.0} req/s below floor {min_rps:.0} req/s ({key})"
                )),
                None => violations
                    .push(format!("report lacks a {mode_name} req_per_s entry ({key})")),
            }
        }
    }
    violations
}

/// One bench control-channel op (stats, the reload to full evaluation)
/// with a fresh connection per attempt. The control channel shares the
/// server with the load passes, so under `ATTENTIVE_FAULT` injection
/// with `--retries` armed it must ride out a torn write exactly like
/// the drivers do; with `retries` 0 this is a single plain attempt.
fn control_retry<T>(
    addr: &str,
    retries: u32,
    what: &str,
    op: impl Fn(&mut Client) -> attentive::error::Result<T>,
) -> anyhow::Result<T> {
    let mut last = String::new();
    for attempt in 0..=retries {
        if attempt > 0 {
            std::thread::sleep(std::time::Duration::from_millis(20 << attempt.min(5)));
        }
        match Client::connect(addr) {
            Ok(mut client) => match op(&mut client) {
                Ok(v) => return Ok(v),
                Err(e) => last = e.to_string(),
            },
            Err(e) => last = e.to_string(),
        }
    }
    bail!("bench control op {what} failed after {retries} retries: {last}")
}

fn cmd_bench_serve(args: &Args) -> anyhow::Result<()> {
    let requests = args.get_parse("requests", 4_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let connections = args.get_parse("connections", 4usize).map_err(|e| anyhow::anyhow!(e))?;
    let pipeline = args.get_parse("pipeline", 8usize).map_err(|e| anyhow::anyhow!(e))?;
    let hard = args.get_parse("hard", 0.5f64).map_err(|e| anyhow::anyhow!(e))?;
    let sparse_eps = args.get_parse("sparse-eps", 0.05f64).map_err(|e| anyhow::anyhow!(e))?;
    let batch_examples =
        args.get_parse("batch-examples", 16usize).map_err(|e| anyhow::anyhow!(e))?;

    let open_loop = args.has("open-loop");
    let churn = args.get_parse("churn", 0usize).map_err(|e| anyhow::anyhow!(e))?;
    let retries = args.get_parse("retries", 0u32).map_err(|e| anyhow::anyhow!(e))?;
    let deadline_ms = args.get_parse("deadline-ms", 0u32).map_err(|e| anyhow::anyhow!(e))?;
    let loadcfg = |addr: String, mode: ClientMode| LoadGenConfig {
        addr,
        connections,
        requests,
        pipeline,
        hard_fraction: hard,
        mode,
        sparse_eps,
        batch_size: batch_examples,
        seed: 1, // same seed every pass -> identical traffic
        open_loop,
        churn_cycles: churn,
        retries,
        deadline_ms,
        ..Default::default()
    };
    let mut table = Table::new(&[
        "serving",
        "req/s",
        "avg feats",
        "p50",
        "p99",
        "B/req",
        "answered",
        "shed",
    ]);
    let row = |table: &mut Table, name: &str, r: &attentive::server::loadgen::LoadReport| {
        table.row(&[
            name.into(),
            format!("{:.0}", r.req_per_s()),
            format!("{:.1}", r.avg_features()),
            format!("{}", r.feature_percentile(0.50)),
            format!("{}", r.feature_percentile(0.99)),
            format!("{:.0}", r.bytes_per_req()),
            format!("{}", r.answered + r.learned),
            format!("{}", r.overloaded),
        ]);
    };

    let mut passes: Vec<(String, attentive::server::loadgen::LoadReport)> = Vec::new();

    // Open-loop runs exist to prove the many-mostly-idle-connections
    // claim; a single shed (or transport error) falsifies it, so fail
    // the command rather than quietly writing a report. Likewise with
    // --retries armed: fault recovery promises every request an intact
    // answer, so a residual error after the retry budget is a real
    // failure — this is what the ATTENTIVE_FAULT CI smoke gates on.
    let check_pass = |name: &str,
                      r: &attentive::server::loadgen::LoadReport|
     -> anyhow::Result<()> {
        if open_loop && (r.overloaded > 0 || r.errors > 0) {
            bail!(
                "open-loop pass {name}: {} overloaded shed(s), {} error(s) across {} \
                 connections — zero of each expected",
                r.overloaded,
                r.errors,
                connections
            );
        }
        if retries > 0 && r.errors > 0 {
            bail!(
                "pass {name}: {} error(s) survived a {}-retry budget \
                 ({} re-sent, {} reconnect(s))",
                r.errors,
                retries,
                r.retries,
                r.reconnects
            );
        }
        Ok(())
    };

    // Which transport produced this report — resolved from the actual
    // server config in loopback mode (so a --server-config file's
    // io_backend is honored), from the flag/env for external servers.
    let report_backend: attentive::config::IoBackend;
    // Server-side robustness counters stamped into the JSON report
    // (fetched over the control channel at the end of the run):
    // (worker_panics, batch_shed, deadline_sheds).
    let mut shed_counters: Option<(u64, u64, u64)> = None;

    if let Some(addr) = args.opt("addr") {
        report_backend = match args.opt("io-backend") {
            Some(name) => {
                attentive::config::IoBackend::from_name(name).map_err(|e| anyhow::anyhow!(e))?
            }
            None => attentive::config::IoBackend::default_from_env(),
        };
        // External server: one pass, on the selected wire mode
        // (--model routes it to a named shard; required for classify).
        let mode = ClientMode::from_name(&args.get("mode", "v1-dense"))
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut cfg = loadcfg(addr.to_string(), mode);
        cfg.model = args.opt("model").map(str::to_string);
        let report = loadgen::run(&cfg)?;
        check_pass(mode.name(), &report)?;
        row(&mut table, mode.name(), &report);
        println!("{}", table.render());
        if report.total_voters > 0 {
            println!(
                "classify: {:.1} features/request across {:.1} voters/request \
                 ({:.1} features/voter)",
                report.avg_features(),
                report.total_voters as f64 / report.answered.max(1) as f64,
                report.avg_features_per_voter()
            );
        }
        passes.push((mode.name().to_string(), report));
        // Best-effort: an external server still answers the stats op on
        // a fresh control connection; skip the stamp if it cannot.
        if let Ok(stats) = control_retry(addr, retries, "stats", |c| c.stats()) {
            shed_counters = Some((stats.worker_panics, stats.batch_shed, stats.deadline_sheds));
        }
    } else {
        // Loopback comparison: identical traffic over the three wire
        // modes against the attentive model, a multiclass classify pass
        // against the co-hosted ensemble shard, then a v1-dense pass
        // under full evaluation (the attention baseline), switched via
        // the hot-reload control channel.
        let attentive_snapshot = load_or_train_snapshot(args)?;
        let mut full_snapshot = attentive_snapshot.clone();
        full_snapshot.boundary = attentive::stst::boundary::AnyBoundary::Full;
        let ensemble_snapshot = train_quick_ensemble()?;

        let mut srv_cfg = server_config_from_args(args)?;
        srv_cfg.listen = "127.0.0.1:0".into();
        // Always host a trainer for the learn/mixed passes. They drive a
        // dedicated third shard so the default shard's reload-to-full
        // comparison below is never racing trainer publishes.
        if srv_cfg.trainer.is_none() {
            srv_cfg.trainer = Some(TrainerWireConfig::default());
        }
        let server = TcpServer::serve_models(
            &srv_cfg,
            vec![
                ("default".to_string(), attentive_snapshot.clone().into()),
                ("digits".to_string(), ensemble_snapshot.into()),
                ("learn".to_string(), attentive_snapshot.into()),
            ],
        )?;
        report_backend = srv_cfg.io_backend;
        let addr = server.local_addr().to_string();

        if open_loop {
            // Open loop is the many-idle-connections scaling check, not
            // a wire comparison: run exactly one pass on the selected
            // mode (default v2-binary) so `--connections 2000` costs
            // one sweep, not five.
            let mode = ClientMode::from_name(&args.get("mode", "v2-binary"))
                .map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "loopback server on {addr} ({} backend): open loop, {requests} requests \
                 across {connections} mostly-idle connections ({}) ...",
                srv_cfg.io_backend.name(),
                mode.name()
            );
            let mut cfg = loadcfg(addr.clone(), mode);
            if mode == ClientMode::Classify {
                cfg.model = Some("digits".to_string());
                cfg.digits = vec![1, 2, 3];
            }
            let report = loadgen::run(&cfg)?;
            check_pass(mode.name(), &report)?;
            row(&mut table, mode.name(), &report);
            passes.push((mode.name().to_string(), report));
            println!("{}", table.render());
            let stats = control_retry(&addr, retries, "stats", |c| c.stats())?;
            server.shutdown();
            shed_counters = Some((stats.worker_panics, stats.batch_shed, stats.deadline_sheds));
            println!(
                "server totals: {} served, {} conns, {} shed, {} deadline shed(s) — zero \
                 overload sheds required",
                stats.served, stats.accepted_conns, stats.overloaded, stats.deadline_sheds
            );
        } else {
            println!(
                "loopback server on {addr} ({} backend): {requests} requests × {} passes ...",
                srv_cfg.io_backend.name(),
                ClientMode::ALL.len() + 5
            );

            for mode in ClientMode::ALL {
                let report = loadgen::run(&loadcfg(addr.clone(), mode))?;
                check_pass(mode.name(), &report)?;
                row(&mut table, mode.name(), &report);
                passes.push((mode.name().to_string(), report));
            }

            // Batched pass: the same digit traffic as the v2-binary
            // singles pass, packed --batch-examples per SCORE_BATCH
            // frame — each frame costs one queue slot and one worker
            // wakeup. Tallies are per example, so this row's req/s
            // divides by the v2-binary row's to give the batching
            // speedup directly.
            let batch_report = loadgen::run(&loadcfg(addr.clone(), ClientMode::Batch))?;
            check_pass("batch", &batch_report)?;
            row(&mut table, "batch", &batch_report);
            passes.push(("batch".to_string(), batch_report));

            // Multiclass pass: native binary classify frames against the
            // co-hosted all-pairs ensemble shard.
            let classify_report = loadgen::run(&LoadGenConfig {
                model: Some("digits".to_string()),
                digits: vec![1, 2, 3],
                ..loadcfg(addr.clone(), ClientMode::Classify)
            })?;
            check_pass("classify", &classify_report)?;
            row(&mut table, "classify", &classify_report);
            passes.push(("classify".to_string(), classify_report));

            // Online-learning passes: pure learn traffic, then a 50/50
            // learn+score mix, both against the dedicated "learn" shard
            // (LEARN_SPARSE frames under protocol v4).
            let learn_report = loadgen::run(&LoadGenConfig {
                model: Some("learn".to_string()),
                ..loadcfg(addr.clone(), ClientMode::Learn)
            })?;
            check_pass("learn", &learn_report)?;
            row(&mut table, "learn", &learn_report);
            passes.push(("learn".to_string(), learn_report));
            let mixed_report = loadgen::run(&LoadGenConfig {
                model: Some("learn".to_string()),
                ..loadcfg(addr.clone(), ClientMode::Mixed)
            })?;
            check_pass("mixed", &mixed_report)?;
            row(&mut table, "mixed", &mixed_report);
            passes.push(("mixed".to_string(), mixed_report));

            control_retry(&addr, retries, "reload", |c| c.reload(&full_snapshot))?;
            let full_report = loadgen::run(&loadcfg(addr.clone(), ClientMode::V1Dense))?;
            check_pass("full(v1-dense)", &full_report)?;
            row(&mut table, "full(v1-dense)", &full_report);

            println!("{}", table.render());
            let stats = control_retry(&addr, retries, "stats", |c| c.stats())?;
            server.shutdown();
            shed_counters = Some((stats.worker_panics, stats.batch_shed, stats.deadline_sheds));
            println!(
                "server totals: {} served, early-exit rate {:.3}, {} reload(s), {} conns, {} shed",
                stats.served,
                stats.early_exit_rate,
                stats.reloads,
                stats.accepted_conns,
                stats.overloaded
            );
            for m in &stats.models {
                if m.trainer && m.learn_examples > 0 {
                    println!(
                        "learn shard {:?}: {} examples → {} updates, {} publish(es) \
                         (serving gen {}), {} shed, {:.1} features/example",
                        m.name,
                        m.learn_examples,
                        m.learn_updates,
                        m.learn_publishes,
                        m.gen,
                        m.learn_sheds,
                        m.learn_features as f64 / m.learn_examples.max(1) as f64
                    );
                }
            }
            let v1 = &passes[0].1;
            let v2b = &passes[2].1;
            let batch = &passes[3].1;
            if v1.req_per_s() > 0.0 {
                println!(
                    "wire: v2-binary {:.0} req/s vs v1-dense {:.0} req/s ({:.1}x), \
                     {:.0} vs {:.0} request bytes",
                    v2b.req_per_s(),
                    v1.req_per_s(),
                    v2b.req_per_s() / v1.req_per_s(),
                    v2b.bytes_per_req(),
                    v1.bytes_per_req(),
                );
            }
            if v2b.req_per_s() > 0.0 {
                println!(
                    "batch: {:.0} examples/s vs v2-binary {:.0} req/s ({:.1}x at {} \
                     examples per SCORE_BATCH frame)",
                    batch.req_per_s(),
                    v2b.req_per_s(),
                    batch.req_per_s() / v2b.req_per_s(),
                    batch_examples,
                );
            }
            if full_report.avg_features() > 0.0 {
                println!(
                    "attention saves {:.1}x features per request ({:.1} vs {:.1} of 784)",
                    full_report.avg_features() / v1.avg_features().max(1e-9),
                    v1.avg_features(),
                    full_report.avg_features()
                );
            }
            passes.push(("full-v1-dense".to_string(), full_report));
        }
    }

    let recovered = passes
        .iter()
        .fold((0u64, 0u64), |acc, (_, r)| (acc.0 + r.retries, acc.1 + r.reconnects));
    if recovered.0 > 0 || recovered.1 > 0 {
        println!(
            "fault recovery: {} request(s) re-sent over {} reconnect(s)",
            recovered.0, recovered.1
        );
    }

    let mut report_json = loadgen::report_to_json(requests, &passes);
    // Stamp the transport backend so floors can gate the two backends
    // independently (`event_loop_*` floor keys), plus the server-side
    // robustness counters so a CI run's report records contained
    // panics and shed work alongside the throughput numbers.
    if let Json::Obj(pairs) = &mut report_json {
        pairs.push(("io_backend".to_string(), Json::Str(report_backend.name().to_string())));
        if let Some((worker_panics, batch_shed, deadline_sheds)) = shed_counters {
            pairs.push(("worker_panics".to_string(), Json::Num(worker_panics as f64)));
            pairs.push(("batch_shed".to_string(), Json::Num(batch_shed as f64)));
            pairs.push(("deadline_sheds".to_string(), Json::Num(deadline_sheds as f64)));
        }
    }
    if let Some(path) = args.opt("json") {
        attentive::metrics::export::to_json_file(&report_json, std::path::Path::new(path))?;
        println!("bench report written to {path}");
    }
    if let Some(floors_path) = args.opt("floors") {
        let text = std::fs::read_to_string(floors_path).context("reading floors file")?;
        let floors =
            Json::parse(&text).map_err(|e| anyhow::anyhow!("floors {floors_path}: {e}"))?;
        let violations = check_bench_floors(&report_json, &floors);
        if violations.is_empty() {
            println!("bench floors OK ({floors_path})");
        } else {
            for v in &violations {
                eprintln!("FLOOR REGRESSION: {v}");
            }
            bail!("{} bench floor(s) violated", violations.len());
        }
    }
    Ok(())
}
