//! `attentive` — CLI launcher for the Stochastic Focus of Attention stack.
//!
//! Subcommands:
//! * `train`       — run one experiment config (or the paper default) and
//!   print the Figure-3-style summary row.
//! * `sweep`       — run every `*.json` config in a directory.
//! * `simulate`    — Figure 2 boundary validation (decision errors +
//!   stopping times).
//! * `serve`       — train a model, then serve early-stopped predictions
//!   over synthetic traffic and print throughput/feature stats.
//! * `init-config` — write a default config to edit.
//! * `export-idx`  — snapshot the synthetic digit set as MNIST IDX files.

use std::path::PathBuf;

use anyhow::{bail, Context};

use attentive::config::ExperimentConfig;
use attentive::coordinator::scheduler::{run_experiment, run_sweep};
use attentive::coordinator::service::{ModelSnapshot, PredictionService};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::learner::OnlineLearner;
use attentive::metrics::export::{curves_to_csv, Table};
use attentive::sim::bridge::{simulate_decision_errors, BridgeSimConfig};
use attentive::sim::stopping::{fit_sqrt, simulate_stopping_times, StoppingSimConfig};
use attentive::util::cli::Args;

const USAGE: &str = "\
attentive — Rapid Learning with Stochastic Focus of Attention (ICML 2011)

USAGE: attentive <COMMAND> [OPTIONS]

COMMANDS:
  train        [--config exp.json] [--csv out.csv]
  sweep        <dir> [--csv out.csv]
  simulate     [--walks N] [--csv out.csv]
  serve        [--requests N] [--batch B] [--workers W]
  init-config  [out.json]
  export-idx   <dir> [--count N] [--seed S]
  help
";

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]).map_err(|e| anyhow::anyhow!(e))?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "init-config" => {
            let cfg = ExperimentConfig::paper_default();
            let text = cfg.to_json().to_string_pretty();
            match args.pos(0) {
                Some(p) => {
                    std::fs::write(p, text)?;
                    println!("wrote {p}");
                }
                None => println!("{text}"),
            }
            Ok(())
        }
        "export-idx" => {
            let dir = PathBuf::from(args.pos(0).context("export-idx needs a directory")?);
            let count = args.get_parse("count", 10_000usize).map_err(|e| anyhow::anyhow!(e))?;
            let seed = args.get_parse("seed", 7u64).map_err(|e| anyhow::anyhow!(e))?;
            std::fs::create_dir_all(&dir)?;
            let ds = SynthDigits::new(seed).generate(count);
            attentive::data::mnist::write_idx_pair(
                &ds,
                28,
                &dir.join("train-images-idx3-ubyte"),
                &dir.join("train-labels-idx1-ubyte"),
            )?;
            println!("wrote {count} examples to {}", dir.display());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = match args.opt("config") {
        Some(p) => ExperimentConfig::load(std::path::Path::new(p)).context("loading config")?,
        None => ExperimentConfig::paper_default(),
    };
    let dim_hint = 784usize;
    let out = run_experiment(&cfg)?;
    let mut table = Table::new(&[
        "experiment",
        "learner",
        "avg feats/ex",
        "speedup",
        "test err (full)",
        "test err (early)",
        "pred feats",
    ]);
    table.row(&[
        out.name.clone(),
        out.learner.clone(),
        format!("{:.1}", out.avg_features),
        format!("{:.1}x", out.speedup(dim_hint)),
        format!("{:.4}", out.final_test_error),
        format!("{:.4}", out.final_test_error_early),
        format!("{:.1}", out.predict_avg_features),
    ]);
    println!("{}", table.render());
    if let Some(p) = args.opt("csv") {
        let p = PathBuf::from(p);
        curves_to_csv(&[out.mean_features.clone(), out.mean_test_error.clone()], &p)?;
        println!("curves written to {}", p.display());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.pos(0).context("sweep needs a config directory")?);
    let mut configs = Vec::new();
    for entry in std::fs::read_dir(&dir).context("reading sweep dir")? {
        let path = entry?.path();
        if path.extension().is_some_and(|e| e == "json") {
            configs.push(ExperimentConfig::load(&path)?);
        }
    }
    configs.sort_by(|a, b| a.name.cmp(&b.name));
    if configs.is_empty() {
        bail!("no *.json configs in {}", dir.display());
    }
    let outcomes = run_sweep(&configs)?;
    let mut table = Table::new(&[
        "experiment",
        "learner",
        "avg feats/ex",
        "test err (full)",
        "test err (early)",
    ]);
    let mut curves = Vec::new();
    for out in &outcomes {
        table.row(&[
            out.name.clone(),
            out.learner.clone(),
            format!("{:.1}", out.avg_features),
            format!("{:.4}", out.final_test_error),
            format!("{:.4}", out.final_test_error_early),
        ]);
        curves.push(out.mean_features.clone());
        curves.push(out.mean_test_error.clone());
    }
    println!("{}", table.render());
    if let Some(p) = args.opt("csv") {
        curves_to_csv(&curves, &PathBuf::from(p))?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let walks = args.get_parse("walks", 20_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let cfg = BridgeSimConfig { walks_per_cell: walks, ..Default::default() };
    let ns = [256usize, 1024, 4096];
    let deltas = [0.01, 0.05, 0.1, 0.2, 0.3];
    let pts = simulate_decision_errors(&cfg, &ns, &deltas);
    let mut table =
        Table::new(&["n", "delta (target)", "empirical err", "stop rate", "mean stop t"]);
    for p in &pts {
        table.row(&[
            p.n.to_string(),
            format!("{:.3}", p.delta),
            format!("{:.4}", p.empirical),
            format!("{:.3}", p.stop_rate),
            format!("{:.1}", p.mean_stop_time),
        ]);
    }
    println!("Figure 2(a) — decision errors vs theory\n{}", table.render());

    let scfg = StoppingSimConfig::default();
    let ns2 = [64usize, 128, 256, 512, 1024, 2048, 4096];
    let spts = simulate_stopping_times(&scfg, &ns2);
    let (c, r2) = fit_sqrt(&spts);
    let mut t2 = Table::new(&["n", "mean stop", "std", "wald bound"]);
    for p in &spts {
        t2.row(&[
            p.n.to_string(),
            format!("{:.1}", p.mean_stop),
            format!("{:.1}", p.std_stop),
            format!("{:.1}", p.wald_bound),
        ]);
    }
    println!(
        "Figure 2(b) — stopping times (fit: E[T] ≈ {c:.2}·sqrt(n), R² = {r2:.4})\n{}",
        t2.render()
    );
    if let Some(p) = args.opt("csv") {
        use attentive::metrics::curve::Curve;
        let mut err = Curve::new("fig2a/empirical-error");
        for q in &pts {
            err.push(q.n as f64 * 1000.0 + q.delta, q.empirical);
        }
        let mut stop = Curve::new("fig2b/mean-stop");
        for q in &spts {
            stop.push(q.n as f64, q.mean_stop);
        }
        curves_to_csv(&[err, stop], &PathBuf::from(p))?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let requests = args.get_parse("requests", 2_000usize).map_err(|e| anyhow::anyhow!(e))?;
    let batch = args.get_parse("batch", 16usize).map_err(|e| anyhow::anyhow!(e))?;
    let workers = args.get_parse("workers", 2usize).map_err(|e| anyhow::anyhow!(e))?;

    // Train an attentive model quickly, then serve synthetic traffic.
    let cfg = ExperimentConfig::paper_default();
    let (train, _) = attentive::coordinator::factory::build_task(&cfg)?;
    let mut learner =
        attentive::learner::attentive::attentive_pegasos(train.dim(), cfg.lambda, 0.1);
    Trainer::new(TrainerConfig { curves: false, eval_every: 0, ..Default::default() })
        .fit(&mut learner, &train);
    let weights: Vec<f64> = learner.weights().to_vec();
    let var = {
        let vc = learner.var_cache_mut();
        let a = vc.var_sn(1.0, &weights);
        let b = vc.var_sn(-1.0, &weights);
        a.max(b)
    };
    let snapshot = ModelSnapshot {
        weights,
        var_sn: var,
        boundary: attentive::stst::boundary::AnyBoundary::Constant {
            delta: 0.1,
            paper_literal: false,
        },
        // Permuted: pixel order is spatially correlated, violating the
        // bridge's exchangeability assumption (see DESIGN.md §4).
        policy: attentive::margin::policy::CoordinatePolicy::Permuted,
    };

    let (handle, run) =
        PredictionService::new(snapshot, batch, 1024, 0).with_workers(workers).spawn();
    let t0 = std::time::Instant::now();
    // Client threads generate digit traffic and block on responses.
    let clients = 8usize;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let handle = handle.clone();
            let per_client = requests / clients;
            scope.spawn(move || {
                let mut gen = SynthDigits::new(99 + c as u64);
                for i in 0..per_client {
                    let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
                    let img: Vec<f64> = gen.render(digit);
                    let _ = handle.score(img);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let s = run.stats.snapshot();
    drop(handle);
    run.join();
    println!(
        "served {} requests in {:.3}s ({:.0} req/s), avg features/prediction {:.1} of 784, batches {}",
        s.served,
        dt,
        s.served as f64 / dt,
        s.avg_features(),
        s.batches
    );
    Ok(())
}
