//! The sequential partial-sum walker — Algorithm 1's inner test.
//!
//! Walks coordinates in a policy-chosen order, accumulating the signed
//! partial margin `y·Σ_{j≤i} w_j x_j` and the variance prefix
//! `Σ_{j≤i} w_j² var_y(x_j)` in lockstep, and consults the boundary after
//! every coordinate. Stops as soon as
//!
//! ```text
//! y·S_i  >  θ + τ(δ, var̂(S_n))
//! ```
//!
//! (Algorithm 1 line 4, with θ = 1 for the Pegasos hinge).
//!
//! **Variance prefix trick.** `var(S_n) = Σ_j w_j² var(x_j)` over *all* n
//! coordinates would cost O(n) up front — exactly what we are trying to
//! avoid. But the remaining-sum variance is what actually matters for the
//! bridge: conditionally on `S_i`, only the unevaluated coordinates are
//! random. We therefore maintain `V_total` once per example via a lazily
//! refreshed full pass (amortized over `refresh_every` examples, O(n/R)
//! per example) *or* — the default — use the exact running total
//! maintained incrementally by the owning learner, which is possible
//! because Pegasos updates touch every coordinate anyway only on margin
//! violations. The walker itself is agnostic: it receives `var_sn` from
//! its caller and costs O(1) per coordinate.

use crate::margin::policy::OrderGenerator;
use crate::stst::boundary::{Boundary, StopContext};

/// Why the walk terminated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalkOutcome {
    /// Crossed the stopping boundary: example declared unimportant.
    EarlyStopped,
    /// Exhausted a fixed budget (budgeted baseline).
    BudgetExhausted,
    /// Evaluated every coordinate: full margin available.
    Completed,
}

/// Result of one sequential margin evaluation.
#[derive(Debug, Clone, Copy)]
pub struct WalkResult {
    /// Signed partial margin `y·S_i` at termination (full margin when
    /// `outcome == Completed`).
    pub partial_margin: f64,
    /// Number of feature evaluations spent (with-replacement policies may
    /// evaluate a coordinate twice; each draw counts, as in the paper).
    pub evaluated: usize,
    /// How the walk ended.
    pub outcome: WalkOutcome,
    /// The boundary level at the stopping step (diagnostics).
    pub level: f64,
}

impl WalkResult {
    /// Did this walk decide the example is unimportant (skip update)?
    /// Budget exhaustion decides from the truncated margin against θ.
    pub fn skip_update(&self, theta: f64) -> bool {
        match self.outcome {
            WalkOutcome::EarlyStopped => true,
            WalkOutcome::BudgetExhausted | WalkOutcome::Completed => self.partial_margin >= theta,
        }
    }
}

/// Reusable sequential walker. Holds no per-example state; `walk` is the
/// hot function (called once per training example).
#[derive(Debug, Default, Clone)]
pub struct Walker {
    /// Skip boundary checks for the first `min_evaluations` coordinates.
    /// Guards against stopping on near-zero evidence before the variance
    /// estimate has any signal. 0 = check from the first coordinate.
    pub min_evaluations: usize,
}

impl Walker {
    /// Walker that checks the boundary from the first coordinate on.
    pub fn new() -> Self {
        Self { min_evaluations: 0 }
    }

    /// Run the sequential test for one example.
    ///
    /// * `w`, `x` — weight and feature vectors (dense, same length).
    /// * `y` — label in {−1, +1}.
    /// * `order` — coordinate visit order from a
    ///   [`crate::margin::policy::OrderGenerator`]; may contain repeats.
    /// * `theta` — margin decision threshold (1.0 for the Pegasos hinge).
    /// * `var_sn` — estimated variance of the full sum (see module docs).
    /// * `boundary` — the stopping rule.
    #[inline]
    pub fn walk<B: Boundary + ?Sized>(
        &self,
        w: &[f64],
        x: &[f64],
        y: f64,
        order: &[usize],
        theta: f64,
        var_sn: f64,
        boundary: &B,
    ) -> WalkResult {
        debug_assert_eq!(w.len(), x.len());
        let n = order.len();
        let mut ctx = StopContext { evaluated: 0, total: n, theta, var_sn };
        let cap = boundary.budget(&ctx).unwrap_or(n).min(n);

        // Evidence-free boundaries (budgeted/full) take a branch-free fast
        // path: accumulate `cap` products, decide at the end.
        if !boundary.is_evidence_based() {
            let mut s = 0.0;
            for &j in &order[..cap] {
                s += w[j] * x[j];
            }
            let outcome =
                if cap < n { WalkOutcome::BudgetExhausted } else { WalkOutcome::Completed };
            return WalkResult { partial_margin: y * s, evaluated: cap, outcome, level: f64::INFINITY };
        }

        let mut s = 0.0;
        let mut level = f64::INFINITY;
        for (i, &j) in order[..cap].iter().enumerate() {
            s += w[j] * x[j];
            ctx.evaluated = i + 1;
            if ctx.evaluated < self.min_evaluations.max(1) {
                continue;
            }
            level = boundary.level(&ctx);
            // Algorithm 1: stop when the *signed* partial margin clears
            // θ + τ — the walk is on y·S_i so one-sided stopping suffices
            // (only confidently-correct examples are skipped). STRICTLY
            // greater: with w = 0 the variance estimate (and hence τ) is
            // 0 and the partial margin is exactly θ-adjacent; `>=` would
            // deadlock a θ=0 learner (perceptron) at w = 0 forever.
            if y * s > theta + level {
                return WalkResult {
                    partial_margin: y * s,
                    evaluated: ctx.evaluated,
                    outcome: WalkOutcome::EarlyStopped,
                    level,
                };
            }
        }
        WalkResult { partial_margin: y * s, evaluated: cap, outcome: WalkOutcome::Completed, level }
    }

    /// Lazy-order variant of [`Self::walk`]: coordinates are drawn from
    /// the policy generator one at a time, so an early stop after k
    /// coordinates costs O(k·policy-step) instead of the O(n) full-order
    /// materialization. Visited coordinates are appended to `visited`
    /// (in draw order, duplicates included) for the caller's variance
    /// update. Semantics are otherwise identical to `walk` over the order
    /// the generator would have materialized.
    #[inline]
    pub fn walk_lazy<B: Boundary + ?Sized>(
        &self,
        w: &[f64],
        x: &[f64],
        y: f64,
        orders: &mut OrderGenerator,
        theta: f64,
        var_sn: f64,
        boundary: &B,
        visited: &mut Vec<usize>,
    ) -> WalkResult {
        debug_assert_eq!(w.len(), x.len());
        let n = w.len();
        visited.clear();
        orders.begin_example();
        let mut ctx = StopContext { evaluated: 0, total: n, theta, var_sn };
        let cap = boundary.budget(&ctx).unwrap_or(n).min(n);

        if !boundary.is_evidence_based() {
            if cap == n {
                // Full computation is order-invariant: use the exact dense
                // dot (the reference Pegasos semantics) instead of paying
                // the policy's per-draw cost — ~50x faster for the
                // weight-sampled policy at n = 784.
                let s = crate::margin::dot(w, x);
                visited.extend(0..n);
                return WalkResult {
                    partial_margin: y * s,
                    evaluated: n,
                    outcome: WalkOutcome::Completed,
                    level: f64::INFINITY,
                };
            }
            let mut s = 0.0;
            for _ in 0..cap {
                let j = orders.next_coord();
                visited.push(j);
                s += w[j] * x[j];
            }
            return WalkResult {
                partial_margin: y * s,
                evaluated: cap,
                outcome: WalkOutcome::BudgetExhausted,
                level: f64::INFINITY,
            };
        }

        let mut s = 0.0;
        let mut level = f64::INFINITY;
        for i in 0..cap {
            let j = orders.next_coord();
            visited.push(j);
            s += w[j] * x[j];
            ctx.evaluated = i + 1;
            if ctx.evaluated < self.min_evaluations.max(1) {
                continue;
            }
            level = boundary.level(&ctx);
            if y * s > theta + level {
                return WalkResult {
                    partial_margin: y * s,
                    evaluated: ctx.evaluated,
                    outcome: WalkOutcome::EarlyStopped,
                    level,
                };
            }
        }
        WalkResult { partial_margin: y * s, evaluated: cap, outcome: WalkOutcome::Completed, level }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stst::boundary::{BudgetedBoundary, ConstantBoundary, TrivialBoundary};

    fn seq(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    #[test]
    fn trivial_boundary_computes_full_margin() {
        let w = [0.5, -1.0, 2.0, 0.25];
        let x = [1.0, 1.0, -1.0, 4.0];
        let r = Walker::new().walk(&w, &x, 1.0, &seq(4), 1.0, 10.0, &TrivialBoundary);
        assert_eq!(r.outcome, WalkOutcome::Completed);
        assert_eq!(r.evaluated, 4);
        let full: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((r.partial_margin - full).abs() < 1e-12);
    }

    #[test]
    fn budgeted_stops_at_k() {
        let w = vec![1.0; 100];
        let x = vec![1.0; 100];
        let r = Walker::new().walk(&w, &x, 1.0, &seq(100), 1.0, 10.0, &BudgetedBoundary::new(7));
        assert_eq!(r.outcome, WalkOutcome::BudgetExhausted);
        assert_eq!(r.evaluated, 7);
        assert!((r.partial_margin - 7.0).abs() < 1e-12);
        // truncated margin 7 >= theta 1 -> skip
        assert!(r.skip_update(1.0));
    }

    #[test]
    fn constant_boundary_early_stops_confident_example() {
        // Strong aligned example: partial margin grows by 1 per step;
        // tau = sqrt(4 * log(1/sqrt(0.1))) ≈ 2.15, theta=1 -> stop when
        // y*S_i >= 3.15, i.e. at step 4.
        let n = 100;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let b = ConstantBoundary::new(0.1);
        let r = Walker::new().walk(&w, &x, 1.0, &seq(n), 1.0, 4.0, &b);
        assert_eq!(r.outcome, WalkOutcome::EarlyStopped);
        assert_eq!(r.evaluated, 4);
        assert!(r.skip_update(1.0));
    }

    #[test]
    fn misaligned_example_never_early_stops() {
        // y*S_i is always negative: the one-sided test cannot fire, and
        // the learner will see the full (violating) margin.
        let n = 50;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let b = ConstantBoundary::new(0.1);
        let r = Walker::new().walk(&w, &x, -1.0, &seq(n), 1.0, 4.0, &b);
        assert_eq!(r.outcome, WalkOutcome::Completed);
        assert_eq!(r.evaluated, n);
        assert!(!r.skip_update(1.0));
    }

    #[test]
    fn order_with_repeats_counts_each_draw() {
        let w = [10.0, 0.0];
        let x = [1.0, 0.0];
        let order = [0usize, 0, 0]; // with-replacement draws
        let r = Walker::new().walk(&w, &x, 1.0, &order, 1.0, 1.0, &TrivialBoundary);
        assert_eq!(r.evaluated, 3);
        assert!((r.partial_margin - 30.0).abs() < 1e-12); // re-adds the product per draw
    }

    #[test]
    fn min_evaluations_defers_stopping() {
        let n = 100;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let b = ConstantBoundary::new(0.1);
        let r = Walker { min_evaluations: 10 }.walk(&w, &x, 1.0, &seq(n), 1.0, 4.0, &b);
        assert_eq!(r.outcome, WalkOutcome::EarlyStopped);
        assert_eq!(r.evaluated, 10);
    }

    #[test]
    fn higher_variance_stops_later() {
        let n = 1000;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let b = ConstantBoundary::new(0.1);
        let lo = Walker::new().walk(&w, &x, 1.0, &seq(n), 1.0, 1.0, &b).evaluated;
        let hi = Walker::new().walk(&w, &x, 1.0, &seq(n), 1.0, 100.0, &b).evaluated;
        assert!(hi > lo, "var 100 stop {hi} should be later than var 1 stop {lo}");
    }

    #[test]
    fn smaller_delta_stops_later() {
        let n = 1000;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let strict = Walker::new()
            .walk(&w, &x, 1.0, &seq(n), 1.0, 25.0, &ConstantBoundary::new(0.01))
            .evaluated;
        let lax = Walker::new()
            .walk(&w, &x, 1.0, &seq(n), 1.0, 25.0, &ConstantBoundary::new(0.3))
            .evaluated;
        assert!(strict > lax);
    }

    #[test]
    fn completed_walk_uses_full_margin_for_skip_decision() {
        let w = [0.1, 0.1];
        let x = [1.0, 1.0];
        let r = Walker::new().walk(&w, &x, 1.0, &seq(2), 1.0, 0.25, &ConstantBoundary::new(0.1));
        assert_eq!(r.outcome, WalkOutcome::Completed);
        // full margin 0.2 < theta 1.0 -> update needed
        assert!(!r.skip_update(1.0));
    }
}
