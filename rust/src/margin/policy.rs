//! Coordinate-selection policies (paper §4.1).
//!
//! The STST's stopping speed depends on the order coordinates are
//! visited: front-loading informative coordinates drives the partial sum
//! toward the boundary sooner. The paper evaluates three policies —
//! sorted by |w| descending, sampled from the |w| distribution with
//! replacement, and a uniform random permutation — plus the implicit
//! natural order. All four are implemented behind one enum so the
//! ablation bench can sweep them.

use crate::util::rng::Rng64;

/// How the sequential walker orders coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoordinatePolicy {
    /// Natural feature order (0, 1, 2, ...). Cheapest; baseline.
    Sequential,
    /// Descending |w_j| — evaluate heavy coordinates first. The paper's
    /// first policy; only available once weights exist (i.e. not for the
    /// budgeted baseline "since we need to learn the weights to sort").
    SortedByWeight,
    /// Sample coordinates i.i.d. from the |w| distribution *with
    /// replacement* (paper's second policy). Duplicates are allowed and
    /// each draw costs one feature evaluation, exactly as in the paper.
    WeightSampled,
    /// Uniform random permutation (paper's third policy).
    Permuted,
}

impl CoordinatePolicy {
    /// All policies, for sweeps.
    pub const ALL: [CoordinatePolicy; 4] = [
        CoordinatePolicy::Sequential,
        CoordinatePolicy::SortedByWeight,
        CoordinatePolicy::WeightSampled,
        CoordinatePolicy::Permuted,
    ];

    /// Short name used in metric rows.
    pub fn name(self) -> &'static str {
        match self {
            CoordinatePolicy::Sequential => "sequential",
            CoordinatePolicy::SortedByWeight => "sorted",
            CoordinatePolicy::WeightSampled => "weight-sampled",
            CoordinatePolicy::Permuted => "permuted",
        }
    }

    /// Does the policy require learned weights to be meaningful?
    pub fn needs_weights(self) -> bool {
        matches!(self, CoordinatePolicy::SortedByWeight | CoordinatePolicy::WeightSampled)
    }

    /// Parse the kebab-case name emitted by [`Self::name`].
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "sequential" => Ok(CoordinatePolicy::Sequential),
            "sorted" => Ok(CoordinatePolicy::SortedByWeight),
            "weight-sampled" => Ok(CoordinatePolicy::WeightSampled),
            "permuted" => Ok(CoordinatePolicy::Permuted),
            other => Err(format!("unknown coordinate policy {other:?}")),
        }
    }
}

/// Materializes visit orders for a policy. Keeps its own deterministic
/// RNG stream so runs are reproducible given a seed, and reuses its
/// scratch allocation across calls (hot path: one order per example).
#[derive(Debug, Clone)]
pub struct OrderGenerator {
    policy: CoordinatePolicy,
    rng: Rng64,
    /// scratch: last emitted order / lazy permutation buffer
    order: Vec<usize>,
    /// scratch for sorting
    keys: Vec<(f64, usize)>,
    /// scratch: sparse-support visit order (positions into idx/val)
    sparse: Vec<usize>,
    /// scratch: cumulative |w| over a sparse support (weight-sampled)
    sparse_cum: Vec<f64>,
    /// Vose alias table for O(1) weight-sampled draws (rebuilt on refresh)
    alias_prob: Vec<f64>,
    alias_idx: Vec<usize>,
    /// lazy-iteration cursor (see [`Self::begin_example`])
    cursor: usize,
}

impl OrderGenerator {
    /// New generator for `policy`, seeded deterministically.
    pub fn new(policy: CoordinatePolicy, seed: u64) -> Self {
        Self {
            policy,
            rng: Rng64::seed_from_u64(seed),
            order: Vec::new(),
            keys: Vec::new(),
            sparse: Vec::new(),
            sparse_cum: Vec::new(),
            alias_prob: Vec::new(),
            alias_idx: Vec::new(),
            cursor: 0,
        }
    }

    /// The policy this generator implements.
    pub fn policy(&self) -> CoordinatePolicy {
        self.policy
    }

    /// Rebuild the weight-dependent caches (sorted order, sampling
    /// cumulative). Call after every weight update; cheap policies ignore
    /// it. Learners call this lazily — weights only change on margin
    /// violations, so the O(n log n) sort is amortized over many examples.
    pub fn refresh(&mut self, weights: &[f64]) {
        let n = weights.len();
        match self.policy {
            CoordinatePolicy::Sequential | CoordinatePolicy::Permuted => {
                if self.order.len() != n {
                    self.order.clear();
                    self.order.extend(0..n);
                }
            }
            CoordinatePolicy::SortedByWeight => {
                self.keys.clear();
                self.keys.extend(weights.iter().enumerate().map(|(i, w)| (w.abs(), i)));
                // Descending by |w|; ties broken by index for determinism.
                self.keys.sort_unstable_by(|a, b| {
                    b.0.partial_cmp(&a.0).unwrap().then_with(|| a.1.cmp(&b.1))
                });
                self.order.clear();
                self.order.extend(self.keys.iter().map(|&(_, i)| i));
            }
            CoordinatePolicy::WeightSampled => {
                self.build_alias(weights);
                if self.order.len() != n {
                    self.order.resize(n, 0);
                }
            }
        }
    }

    /// Build the Vose alias table for |w|-proportional sampling: O(n) at
    /// refresh (amortized over updates), O(1) per draw afterwards —
    /// replaces the O(log n) CDF binary search that dominated the warm
    /// attentive hot path (EXPERIMENTS.md §Perf).
    fn build_alias(&mut self, weights: &[f64]) {
        let n = weights.len();
        self.alias_prob.clear();
        self.alias_idx.clear();
        let total: f64 = weights.iter().map(|w| w.abs()).sum();
        if total <= 0.0 {
            // uniform fallback
            self.alias_prob.resize(n, 1.0);
            self.alias_idx.extend(0..n);
            return;
        }
        // scaled probabilities p_i * n
        self.alias_prob.extend(weights.iter().map(|w| w.abs() / total * n as f64));
        self.alias_idx.resize(n, 0);
        // Vose: partition into small/large stacks (scratch reused via
        // self.keys to stay allocation-free on the update path).
        self.keys.clear();
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &p) in self.alias_prob.iter().enumerate() {
            if p < 1.0 { small.push(i) } else { large.push(i) }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            self.alias_idx[s] = l;
            self.alias_prob[l] = (self.alias_prob[l] + self.alias_prob[s]) - 1.0;
            if self.alias_prob[l] < 1.0 { small.push(l) } else { large.push(l) }
        }
        // numerical leftovers: saturate
        for i in small.into_iter().chain(large) {
            self.alias_prob[i] = 1.0;
        }
    }

    /// Emit the visit order for the next example, using the caches built
    /// by the last [`Self::refresh`]. The returned slice has length `dim`
    /// (with-replacement sampling emits `dim` draws: the walker will stop
    /// long before exhausting it, and a full pass bounds the cost at one
    /// evaluation per draw like the paper's setup).
    pub fn next(&mut self) -> &[usize] {
        match self.policy {
            CoordinatePolicy::Sequential | CoordinatePolicy::SortedByWeight => {}
            CoordinatePolicy::WeightSampled => {
                // Vose alias draws (O(1) each), same distribution as the
                // lazy path.
                let n = self.order.len();
                for k in 0..n {
                    let i = self.rng.below(n);
                    self.order[k] =
                        if self.rng.f64() < self.alias_prob[i] { i } else { self.alias_idx[i] };
                }
            }
            CoordinatePolicy::Permuted => {
                // Fisher–Yates with our deterministic stream.
                let n = self.order.len();
                for i in (1..n).rev() {
                    let j = self.rng.below(i + 1);
                    self.order.swap(i, j);
                }
            }
        }
        &self.order
    }

    /// Convenience: `refresh` + `next` in one call (tests, one-shot use).
    pub fn order(&mut self, weights: &[f64]) -> &[usize] {
        self.refresh(weights);
        self.next()
    }

    /// Emit a visit order over the *support* of one sparse example:
    /// `idx` holds the nonzero coordinate indices, and the returned
    /// slice holds **positions into `idx`** (length `idx.len()`),
    /// ordered by the same policy the dense path uses — restricted to
    /// the support, since zero coordinates contribute nothing to the
    /// margin and visiting them would waste evaluations. Independent of
    /// the dense caches built by [`Self::refresh`] (separate scratch),
    /// so dense and sparse requests can interleave on one generator.
    ///
    /// * sequential — positions in natural (ascending-index) order;
    /// * sorted — positions by `|w[idx[p]]|` descending, ties by position;
    /// * weight-sampled — `nnz` draws with replacement, `∝ |w[idx[p]]|`
    ///   (uniform fallback when the support carries no weight mass);
    /// * permuted — uniform shuffle of the positions.
    pub fn next_sparse(&mut self, weights: &[f64], idx: &[u32]) -> &[usize] {
        let m = idx.len();
        self.sparse.clear();
        match self.policy {
            CoordinatePolicy::Sequential => self.sparse.extend(0..m),
            CoordinatePolicy::SortedByWeight => {
                self.sparse.extend(0..m);
                self.sparse.sort_unstable_by(|&a, &b| {
                    let wa = weights[idx[a] as usize].abs();
                    let wb = weights[idx[b] as usize].abs();
                    wb.partial_cmp(&wa).unwrap().then_with(|| a.cmp(&b))
                });
            }
            CoordinatePolicy::WeightSampled => {
                self.sparse_cum.clear();
                let mut total = 0.0;
                for &i in idx {
                    total += weights[i as usize].abs();
                    self.sparse_cum.push(total);
                }
                for _ in 0..m {
                    let p = if total > 0.0 {
                        let u = self.rng.f64() * total;
                        self.sparse_cum.partition_point(|&c| c <= u).min(m - 1)
                    } else {
                        self.rng.below(m)
                    };
                    self.sparse.push(p);
                }
            }
            CoordinatePolicy::Permuted => {
                self.sparse.extend(0..m);
                self.rng.shuffle(&mut self.sparse);
            }
        }
        &self.sparse
    }

    /// Begin lazy per-coordinate iteration for one example. The hot path
    /// uses [`Self::next_coord`] instead of materializing a full order:
    /// an early-stopped walk that touches k coordinates then costs
    /// O(k·log n) (weight-sampled) or O(k) (others) instead of the O(n)
    /// (or O(n·log n)) a full-order materialization costs — which would
    /// otherwise dominate and erase the paper's O(√n) win (measured: 62 µs
    /// order materialization vs 1.4 µs walk at n = 784).
    #[inline]
    pub fn begin_example(&mut self) {
        self.cursor = 0;
    }

    /// Yield the next coordinate of the current example's visit order.
    ///
    /// * sequential / sorted — cached order lookup, O(1);
    /// * weight-sampled — one CDF draw (binary search), O(log n);
    /// * permuted — lazy Fisher–Yates step, O(1): position i swaps with a
    ///   uniform j ∈ [i, n), which yields a uniform permutation prefix
    ///   regardless of how much of the buffer previous examples consumed.
    ///
    /// Callers must not exceed `n` calls per example for permutation
    /// policies (the walker caps at `total`); weight-sampled draws are
    /// unbounded.
    #[inline]
    pub fn next_coord(&mut self) -> usize {
        let n = self.order.len();
        debug_assert!(n > 0, "refresh() must run before next_coord()");
        match self.policy {
            CoordinatePolicy::Sequential | CoordinatePolicy::SortedByWeight => {
                let c = self.order[self.cursor];
                self.cursor += 1;
                c
            }
            CoordinatePolicy::WeightSampled => {
                // Vose alias draw: O(1).
                let i = self.rng.below(n);
                if self.rng.f64() < self.alias_prob[i] {
                    i
                } else {
                    self.alias_idx[i]
                }
            }
            CoordinatePolicy::Permuted => {
                let i = self.cursor;
                let j = i + self.rng.below(n - i);
                self.order.swap(i, j);
                self.cursor += 1;
                self.order[i]
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn sequential_is_identity() {
        let mut g = OrderGenerator::new(CoordinatePolicy::Sequential, 0);
        assert_eq!(g.order(&[0.0; 5]), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn sorted_descends_by_abs_weight() {
        let mut g = OrderGenerator::new(CoordinatePolicy::SortedByWeight, 0);
        let order = g.order(&[0.1, -5.0, 2.0, 0.0]).to_vec();
        assert_eq!(order, vec![1, 2, 0, 3]);
    }

    #[test]
    fn sorted_tie_break_deterministic() {
        let mut g = OrderGenerator::new(CoordinatePolicy::SortedByWeight, 0);
        let order = g.order(&[1.0, -1.0, 1.0]).to_vec();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn permuted_is_a_permutation_and_seed_deterministic() {
        let mut g1 = OrderGenerator::new(CoordinatePolicy::Permuted, 42);
        let mut g2 = OrderGenerator::new(CoordinatePolicy::Permuted, 42);
        let o1 = g1.order(&[0.0; 100]).to_vec();
        let o2 = g2.order(&[0.0; 100]).to_vec();
        assert_eq!(o1, o2, "same seed, same permutation");
        let set: HashSet<usize> = o1.iter().copied().collect();
        assert_eq!(set.len(), 100, "must be a permutation");
        let mut g3 = OrderGenerator::new(CoordinatePolicy::Permuted, 43);
        assert_ne!(g3.order(&[0.0; 100]), &o1[..], "different seed differs");
    }

    #[test]
    fn weight_sampled_prefers_heavy_coordinates() {
        let mut g = OrderGenerator::new(CoordinatePolicy::WeightSampled, 7);
        let mut w = vec![0.01; 50];
        w[13] = 10.0; // dominant mass
        let mut hits = 0;
        for _ in 0..20 {
            let order = g.order(&w);
            hits += order.iter().filter(|&&i| i == 13).count();
        }
        // 13 holds 10/10.49 of the mass; over 1000 draws expect ~953 hits.
        assert!(hits > 700, "dominant coordinate drawn {hits}/1000 times");
    }

    #[test]
    fn weight_sampled_with_replacement_has_duplicates() {
        let mut g = OrderGenerator::new(CoordinatePolicy::WeightSampled, 1);
        let order = g.order(&[1.0; 64]).to_vec();
        let set: HashSet<usize> = order.iter().copied().collect();
        assert_eq!(order.len(), 64);
        assert!(set.len() < 64, "i.i.d. draws over 64 slots collide w.h.p.");
    }

    #[test]
    fn weight_sampled_all_zero_falls_back_uniform() {
        let mut g = OrderGenerator::new(CoordinatePolicy::WeightSampled, 1);
        let order = g.order(&[0.0; 16]).to_vec();
        assert_eq!(order.len(), 16);
        assert!(order.iter().all(|&i| i < 16));
    }

    #[test]
    fn sparse_orders_cover_positions_per_policy() {
        let w = [0.1, -5.0, 2.0, 0.0, 1.0, -0.5];
        let idx: [u32; 3] = [1, 3, 4]; // support: |w| = 5.0, 0.0, 1.0
        for policy in CoordinatePolicy::ALL {
            let mut g = OrderGenerator::new(policy, 9);
            let order = g.next_sparse(&w, &idx).to_vec();
            assert_eq!(order.len(), 3, "{policy:?}");
            assert!(order.iter().all(|&p| p < 3), "{policy:?} out of range: {order:?}");
        }
        // Sorted: heaviest support coordinate first.
        let mut g = OrderGenerator::new(CoordinatePolicy::SortedByWeight, 0);
        assert_eq!(g.next_sparse(&w, &idx), &[0, 2, 1]);
        // Sequential: natural position order.
        let mut g = OrderGenerator::new(CoordinatePolicy::Sequential, 0);
        assert_eq!(g.next_sparse(&w, &idx), &[0, 1, 2]);
        // Permuted: a permutation of the positions.
        let mut g = OrderGenerator::new(CoordinatePolicy::Permuted, 3);
        let mut o = g.next_sparse(&w, &idx).to_vec();
        o.sort_unstable();
        assert_eq!(o, vec![0, 1, 2]);
    }

    #[test]
    fn sparse_weight_sampling_prefers_heavy_support() {
        let mut w = vec![0.01; 64];
        w[7] = 10.0;
        let idx: Vec<u32> = vec![2, 7, 50];
        let mut g = OrderGenerator::new(CoordinatePolicy::WeightSampled, 5);
        let mut hits = 0;
        let mut draws = 0;
        for _ in 0..200 {
            for &p in g.next_sparse(&w, &idx) {
                draws += 1;
                if p == 1 {
                    hits += 1; // position 1 = coordinate 7
                }
            }
        }
        assert_eq!(draws, 600);
        assert!(hits > 500, "dominant support coordinate drawn {hits}/600");
        // All-zero support mass falls back to uniform draws.
        let zero = vec![0.0; 64];
        let order = g.next_sparse(&zero, &idx).to_vec();
        assert_eq!(order.len(), 3);
        assert!(order.iter().all(|&p| p < 3));
    }

    #[test]
    fn sparse_order_does_not_clobber_dense_caches() {
        // Interleaving dense and sparse requests on one generator must
        // keep the dense sorted order intact (separate scratch).
        let w = [0.1, -5.0, 2.0, 0.0];
        let mut g = OrderGenerator::new(CoordinatePolicy::SortedByWeight, 0);
        g.refresh(&w);
        let dense_before = g.next().to_vec();
        let _ = g.next_sparse(&w, &[0, 2]);
        assert_eq!(g.next(), &dense_before[..]);
    }

    #[test]
    fn empty_sparse_support_yields_empty_order() {
        for policy in CoordinatePolicy::ALL {
            let mut g = OrderGenerator::new(policy, 1);
            assert!(g.next_sparse(&[1.0, 2.0], &[]).is_empty(), "{policy:?}");
        }
    }

    #[test]
    fn policy_metadata() {
        assert!(CoordinatePolicy::SortedByWeight.needs_weights());
        assert!(!CoordinatePolicy::Permuted.needs_weights());
        let names: HashSet<&str> = CoordinatePolicy::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 4);
    }
}
