//! Sequential margin evaluation — the computational hot path.
//!
//! A margin-based learner's inner loop computes `y·⟨w, x⟩` and compares it
//! to a threshold. This module owns that loop in its *sequential,
//! early-stoppable* form:
//!
//! * [`policy`] — in what order coordinates are visited (paper §4.1:
//!   sorted by |w|, sampled from the weight distribution with
//!   replacement, or randomly permuted);
//! * [`walker`] — the scalar partial-sum walker that consults a
//!   [`crate::stst::Boundary`] after every coordinate (Algorithm 1's
//!   "∃ i s.t. y Σ_{j≤i} w_j x_j ≥ 1 + τ" test), maintaining the
//!   variance prefix incrementally so each step is O(1);
//! * [`evaluator`] — batch-facing evaluators: the native scalar one and a
//!   block-granular one matching the XLA artifact semantics (prefix
//!   margins at block boundaries), plus the exactness bridge between the
//!   two used by tests and the runtime.

pub mod evaluator;
pub mod policy;
pub mod walker;

pub use evaluator::{BlockedEvaluator, ScalarEvaluator};
pub use policy::CoordinatePolicy;
pub use walker::{WalkOutcome, WalkResult, Walker};

/// Dense dot product — the "full computation" reference used by the
/// trivial boundary, tests, and the decision-error audit.
#[inline]
pub fn dot(w: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), x.len());
    // Four-way unrolled accumulation: measurably faster than the naive
    // fold at 784 dims and keeps float summation order deterministic.
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let mut acc2 = 0.0f64;
    let mut acc3 = 0.0f64;
    let chunks = w.len() / 4;
    for c in 0..chunks {
        let i = 4 * c;
        acc0 += w[i] * x[i];
        acc1 += w[i + 1] * x[i + 1];
        acc2 += w[i + 2] * x[i + 2];
        acc3 += w[i + 3] * x[i + 3];
    }
    for i in 4 * chunks..w.len() {
        acc0 += w[i] * x[i];
    }
    (acc0 + acc1) + (acc2 + acc3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 16, 784] {
            let w: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.91).cos()).collect();
            let naive: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((dot(&w, &x) - naive).abs() < 1e-10, "n={n}");
        }
    }
}
