//! Batch-facing margin evaluators: native scalar and block-granular.
//!
//! Two faithful implementations of the sequential test, at different
//! granularities:
//!
//! * [`ScalarEvaluator`] — per-feature stopping: the paper's exact
//!   Algorithm 1 semantics (wraps [`crate::margin::walker::Walker`]).
//! * [`BlockedEvaluator`] — stopping decisions only at multiples of a
//!   block size `B`. This mirrors the TPU/XLA execution model where the
//!   L1 Pallas kernel computes `w⊙x` one VMEM block at a time and emits
//!   the prefix margin after each block (see
//!   `python/compile/kernels/partial_margin.py`); the coordinator then
//!   stops issuing blocks once the prefix clears the boundary. Evaluated
//!   features are charged in whole blocks (`ceil(T/B)·B`).
//!
//! The key invariant — tested here and by proptests — is that the blocked
//! evaluator with `B = 1` is *exactly* the scalar evaluator, and for
//! `B > 1` it stops at the first block boundary at or after the scalar
//! stopping point (never earlier), so its decision-error rate is bounded
//! by the scalar one's.

use crate::stst::boundary::{Boundary, StopContext};

use super::walker::{WalkOutcome, WalkResult, Walker};

/// Exact per-feature sequential evaluator (Algorithm 1 semantics).
#[derive(Debug, Default, Clone)]
pub struct ScalarEvaluator {
    walker: Walker,
}

impl ScalarEvaluator {
    /// New evaluator checking the boundary at every coordinate.
    pub fn new() -> Self {
        Self { walker: Walker::new() }
    }

    /// Sequentially evaluate `y·⟨w,x⟩` under `boundary`. See
    /// [`Walker::walk`] for parameter semantics.
    #[inline]
    pub fn evaluate<B: Boundary + ?Sized>(
        &self,
        w: &[f64],
        x: &[f64],
        y: f64,
        order: &[usize],
        theta: f64,
        var_sn: f64,
        boundary: &B,
    ) -> WalkResult {
        self.walker.walk(w, x, y, order, theta, var_sn, boundary)
    }
}

/// Block-granular sequential evaluator (XLA-artifact semantics).
#[derive(Debug, Clone)]
pub struct BlockedEvaluator {
    /// Block size `B` in features. The XLA artifact is compiled for a
    /// fixed `B` (default 16 — 49 blocks for 784-dim digits).
    pub block: usize,
}

impl BlockedEvaluator {
    /// New evaluator stopping only at multiples of `block`.
    pub fn new(block: usize) -> Self {
        assert!(block > 0, "block size must be positive");
        Self { block }
    }

    /// Evaluate with stopping checks at block boundaries only. Features
    /// are *charged* in whole blocks, matching what the accelerator would
    /// actually compute.
    pub fn evaluate<B: Boundary + ?Sized>(
        &self,
        w: &[f64],
        x: &[f64],
        y: f64,
        order: &[usize],
        theta: f64,
        var_sn: f64,
        boundary: &B,
    ) -> WalkResult {
        debug_assert_eq!(w.len(), x.len());
        let n = order.len();
        let mut ctx = StopContext { evaluated: 0, total: n, theta, var_sn };
        let cap = boundary.budget(&ctx).unwrap_or(n).min(n);
        let evidence = boundary.is_evidence_based();

        let mut s = 0.0;
        let mut done = 0;
        let mut level = f64::INFINITY;
        while done < cap {
            let end = (done + self.block).min(cap);
            for &j in &order[done..end] {
                s += w[j] * x[j];
            }
            done = end;
            if evidence && done < n {
                ctx.evaluated = done;
                level = boundary.level(&ctx);
                if y * s > theta + level {
                    return WalkResult {
                        partial_margin: y * s,
                        evaluated: done,
                        outcome: WalkOutcome::EarlyStopped,
                        level,
                    };
                }
            }
        }
        let outcome = if cap < n { WalkOutcome::BudgetExhausted } else { WalkOutcome::Completed };
        WalkResult { partial_margin: y * s, evaluated: done, outcome, level }
    }

    /// Given the per-block prefix margins `prefix[k] = y·S_{(k+1)·B}`
    /// (as produced by the XLA blocked-margin artifact for a whole batch),
    /// find the stopping block under `boundary`. Returns
    /// `(features_charged, stopped_early, margin_at_stop)`. This is the
    /// post-processing the coordinator applies to runtime output; it must
    /// agree with [`Self::evaluate`] — see `blocked_prefix_agreement`.
    pub fn decide_from_prefixes<B: Boundary + ?Sized>(
        &self,
        prefixes: &[f64],
        n: usize,
        theta: f64,
        var_sn: f64,
        boundary: &B,
    ) -> (usize, bool, f64) {
        let mut ctx = StopContext { evaluated: 0, total: n, theta, var_sn };
        for (k, &pm) in prefixes.iter().enumerate() {
            let done = ((k + 1) * self.block).min(n);
            if done >= n {
                break;
            }
            ctx.evaluated = done;
            if boundary.is_evidence_based() && pm > theta + boundary.level(&ctx) {
                return (done, true, pm);
            }
        }
        let full = prefixes.last().copied().unwrap_or(0.0);
        (n, false, full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stst::boundary::{ConstantBoundary, TrivialBoundary};

    fn wx(n: usize) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        let w: Vec<f64> = (0..n).map(|i| ((i * 37 % 17) as f64 - 8.0) / 8.0).collect();
        let x: Vec<f64> = (0..n).map(|i| ((i * 11 % 23) as f64 - 11.0) / 11.0).collect();
        (w, x, (0..n).collect())
    }

    #[test]
    fn block1_equals_scalar() {
        let (w, x, order) = wx(257);
        let b = ConstantBoundary::new(0.2);
        for y in [1.0, -1.0] {
            for var in [0.01, 0.5, 5.0] {
                let s = ScalarEvaluator::new().evaluate(&w, &x, y, &order, 1.0, var, &b);
                let blk = BlockedEvaluator::new(1).evaluate(&w, &x, y, &order, 1.0, var, &b);
                assert_eq!(s.evaluated, blk.evaluated);
                assert_eq!(s.outcome, blk.outcome);
                assert!((s.partial_margin - blk.partial_margin).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn blocked_never_stops_before_scalar_block_boundary() {
        let n = 784;
        let w = vec![0.1; n];
        let x = vec![1.0; n];
        let order: Vec<usize> = (0..n).collect();
        let b = ConstantBoundary::new(0.1);
        let s = ScalarEvaluator::new().evaluate(&w, &x, 1.0, &order, 1.0, 0.5, &b);
        let blk = BlockedEvaluator::new(16).evaluate(&w, &x, 1.0, &order, 1.0, 0.5, &b);
        assert_eq!(s.outcome, WalkOutcome::EarlyStopped);
        assert_eq!(blk.outcome, WalkOutcome::EarlyStopped);
        assert!(blk.evaluated >= s.evaluated);
        assert_eq!(blk.evaluated % 16, 0);
        // and not a block later than needed
        assert!(blk.evaluated < s.evaluated + 16);
    }

    #[test]
    fn blocked_full_margin_matches_dot() {
        let (w, x, order) = wx(100);
        let blk = BlockedEvaluator::new(7).evaluate(&w, &x, 1.0, &order, 1.0, 1e9, &TrivialBoundary);
        let full: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
        assert_eq!(blk.outcome, WalkOutcome::Completed);
        assert!((blk.partial_margin - full).abs() < 1e-10);
    }

    #[test]
    fn blocked_prefix_agreement() {
        // decide_from_prefixes over the artifact-style prefix array must
        // match evaluate() run coordinate-wise.
        let n = 96;
        let block = 16;
        let (w, x, order) = wx(n);
        let bnd = ConstantBoundary::new(0.15);
        for y in [1.0, -1.0] {
            // Build the prefix array the XLA kernel would emit.
            let mut prefixes = Vec::new();
            let mut s = 0.0;
            for k in 0..(n / block) {
                for &j in &order[k * block..(k + 1) * block] {
                    s += w[j] * x[j];
                }
                prefixes.push(y * s);
            }
            let ev = BlockedEvaluator::new(block);
            let direct = ev.evaluate(&w, &x, y, &order, 1.0, 0.8, &bnd);
            let (charged, stopped, margin) =
                ev.decide_from_prefixes(&prefixes, n, 1.0, 0.8, &bnd);
            assert_eq!(charged, direct.evaluated);
            assert_eq!(stopped, direct.outcome == WalkOutcome::EarlyStopped);
            assert!((margin - direct.partial_margin).abs() < 1e-10);
        }
    }

    #[test]
    fn last_block_never_early_stops() {
        // Stopping inside the final block is pointless (the sum is done);
        // both paths must report Completed with the full margin.
        let n = 32;
        let block = 16;
        let w = vec![1.0; n];
        let x = vec![1.0; n];
        let order: Vec<usize> = (0..n).collect();
        let bnd = ConstantBoundary::new(0.5); // very lax
        let r = BlockedEvaluator::new(block).evaluate(&w, &x, 1.0, &order, 1.0, 0.001, &bnd);
        // stops at block 1 (16 features) since margin 16 >> boundary
        assert_eq!(r.outcome, WalkOutcome::EarlyStopped);
        assert_eq!(r.evaluated, 16);
        // but if the crossing only happens in the last block:
        let mut x2 = vec![0.0; n];
        for v in x2.iter_mut().skip(16) {
            *v = 1.0;
        }
        let r2 = BlockedEvaluator::new(block).evaluate(&w, &x2, 1.0, &order, 1.0, 0.001, &bnd);
        assert_eq!(r2.outcome, WalkOutcome::Completed);
        assert_eq!(r2.evaluated, n);
    }
}
