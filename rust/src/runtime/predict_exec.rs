//! Dense batched margin (the MXU matmul path) for test-set evaluation.
//!
//! Artifact contract (`artifacts/predict_b{BATCH}.hlo.txt`, from
//! `python/compile/aot.py::export_predict`):
//!
//! ```text
//! inputs : w f32[DIM], x f32[BATCH, DIM]
//! output : (margins f32[BATCH],)   margins = x @ w
//! ```
//!
//! Used by the serving example and by held-out evaluation when the XLA
//! path is enabled; on a real TPU this is the systolic-array matmul the
//! hardware-adaptation section routes dense work to.

use std::sync::Arc;

use crate::error::{Error, Result};

use super::literal::{mat_f32, to_vec_f64, vec_f32};
use super::margin_exec::shapes;
use super::Runtime;

/// Runs the dense-predict artifact over example batches of any size
/// (internally tiled into compiled-batch chunks).
pub struct DensePredictExecutor {
    rt: Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

impl DensePredictExecutor {
    /// Artifact file name for the compiled batch.
    pub fn artifact_name() -> String {
        format!("predict_b{}.hlo.txt", shapes::BATCH)
    }

    /// Load and compile the artifact.
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self { rt: rt.clone(), exe: rt.load(&Self::artifact_name())? })
    }

    /// Margins for an arbitrary number of examples (row-major features).
    pub fn margins(&self, w: &[f64], features: &[f64], count: usize) -> Result<Vec<f64>> {
        if w.len() != shapes::DIM {
            return Err(Error::DimMismatch {
                expected: shapes::DIM,
                got: w.len(),
                context: "predict weights".into(),
            });
        }
        if features.len() != count * shapes::DIM {
            return Err(Error::DimMismatch {
                expected: count * shapes::DIM,
                got: features.len(),
                context: "predict features".into(),
            });
        }
        let w_lit = vec_f32(w);
        let mut out = Vec::with_capacity(count);
        let mut xbuf = vec![0.0f64; shapes::BATCH * shapes::DIM];
        let mut i = 0;
        while i < count {
            let chunk = (count - i).min(shapes::BATCH);
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            xbuf[..chunk * shapes::DIM]
                .copy_from_slice(&features[i * shapes::DIM..(i + chunk) * shapes::DIM]);
            let outputs = self
                .rt
                .execute(&self.exe, &[w_lit.clone(), mat_f32(&xbuf, shapes::BATCH, shapes::DIM)?])?;
            let m = outputs
                .first()
                .ok_or_else(|| Error::Xla("predict artifact returned empty tuple".into()))?;
            let vals = to_vec_f64(m, shapes::BATCH)?;
            out.extend_from_slice(&vals[..chunk]);
            i += chunk;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_encodes_batch() {
        assert_eq!(DensePredictExecutor::artifact_name(), "predict_b32.hlo.txt");
    }

    #[test]
    fn missing_artifact_is_clean() {
        let rt = Runtime::with_artifact_dir("/definitely-missing").unwrap();
        assert!(matches!(DensePredictExecutor::new(&rt), Err(Error::MissingArtifact(_))));
    }
}
