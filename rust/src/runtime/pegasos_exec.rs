//! Fused Pegasos update+projection step via the L2 artifact.
//!
//! Artifact contract (`artifacts/pegasos_step.hlo.txt`, from
//! `python/compile/aot.py::export_pegasos_step`):
//!
//! ```text
//! inputs : w      f32[DIM]  — current weights
//!          x      f32[DIM]  — violating example
//!          y      f32[]     — its label (±1)
//!          t      f32[]     — update counter (≥ 1)
//!          lam    f32[]     — regularization λ
//! output : (w_new f32[DIM],)
//!          w' = (1 − 1/t)·w + y/(λt)·x ;  w_new = min(1, (1/√λ)/‖w'‖)·w'
//! ```
//!
//! The donated-buffer layout and the fused decay+axpy+projection are the
//! L2 optimizations described in DESIGN.md §6.

use std::sync::Arc;

use crate::error::{Error, Result};

use super::literal::{scalar_f32, to_vec_f64, vec_f32};
use super::margin_exec::shapes;
use super::Runtime;

/// Runs the fused Pegasos step artifact.
pub struct PegasosStepExecutor {
    rt: Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

impl PegasosStepExecutor {
    /// Artifact file name.
    pub const ARTIFACT: &'static str = "pegasos_step.hlo.txt";

    /// Load and compile the artifact.
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self { rt: rt.clone(), exe: rt.load(Self::ARTIFACT)? })
    }

    /// Execute one update step; returns the new weight vector.
    pub fn step(&self, w: &[f64], x: &[f64], y: f64, t: u64, lambda: f64) -> Result<Vec<f64>> {
        if w.len() != shapes::DIM || x.len() != shapes::DIM {
            return Err(Error::DimMismatch {
                expected: shapes::DIM,
                got: w.len().min(x.len()),
                context: "pegasos_exec".into(),
            });
        }
        if t == 0 {
            return Err(Error::Config("pegasos step counter t must be >= 1".into()));
        }
        let outputs = self.rt.execute(
            &self.exe,
            &[vec_f32(w), vec_f32(x), scalar_f32(y), scalar_f32(t as f64), scalar_f32(lambda)],
        )?;
        let w_new = outputs
            .first()
            .ok_or_else(|| Error::Xla("pegasos artifact returned empty tuple".into()))?;
        to_vec_f64(w_new, shapes::DIM)
    }

    /// Reference implementation of the same step in pure rust (used by the
    /// integration test to verify the artifact's numerics and by callers
    /// that want the f64 path).
    pub fn step_reference(w: &[f64], x: &[f64], y: f64, t: u64, lambda: f64) -> Vec<f64> {
        let mu = 1.0 / (lambda * t as f64);
        let decay = 1.0 - 1.0 / t as f64;
        let mut out: Vec<f64> =
            w.iter().zip(x).map(|(&wj, &xj)| decay * wj + mu * y * xj).collect();
        let norm = out.iter().map(|v| v * v).sum::<f64>().sqrt();
        let limit = 1.0 / lambda.sqrt();
        if norm > limit {
            let c = limit / norm;
            out.iter_mut().for_each(|v| *v *= c);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_step_matches_learner_update() {
        // The standalone reference must agree with BoundedPegasos::update
        // (t=1: decay 0, mu=1/λ).
        let w = vec![0.5; shapes::DIM];
        let x = vec![0.25; shapes::DIM];
        let lambda = 0.01;
        let out = PegasosStepExecutor::step_reference(&w, &x, 1.0, 1, lambda);
        // decay = 0 -> w' = (1/λ)·0.25 = 25 per coord; norm = 25·28 = 700
        // limit = 10 -> projected
        let expect_unproj = 25.0;
        let norm = (expect_unproj * expect_unproj * shapes::DIM as f64).sqrt();
        let c = (1.0 / lambda.sqrt()) / norm;
        for v in &out {
            assert!((v - expect_unproj * c).abs() < 1e-9);
        }
    }

    #[test]
    fn reference_no_projection_inside_ball() {
        let mut w = vec![0.0; shapes::DIM];
        w[0] = 0.1;
        let mut x = vec![0.0; shapes::DIM];
        x[0] = 0.1;
        let out = PegasosStepExecutor::step_reference(&w, &x, 1.0, 100, 1.0);
        // mu = 1/100, decay = 0.99 -> w0 = 0.099 + 0.001 = 0.1; norm 0.1 < 1
        assert!((out[0] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn missing_artifact_is_clean() {
        let rt = Runtime::with_artifact_dir("/definitely-missing").unwrap();
        assert!(matches!(
            PegasosStepExecutor::new(&rt),
            Err(Error::MissingArtifact(_))
        ));
    }

    #[test]
    fn zero_t_rejected() {
        // Construct-free check of the validation path: we need an executor
        // to call step(), so only exercise the reference precondition here.
        // (Artifact-backed validation is covered in integration tests.)
        assert!(PegasosStepExecutor::step_reference(&[0.0; 784], &[0.0; 784], 1.0, 1, 0.1)
            .iter()
            .all(|v| *v == 0.0));
    }
}
