//! PJRT runtime: loads and executes the AOT-compiled XLA artifacts.
//!
//! The build-time half lives in `python/compile/aot.py`: JAX/Pallas
//! functions are lowered once to **HLO text** (the interchange format the
//! bundled xla_extension 0.5.1 accepts — jax ≥0.5's serialized protos use
//! 64-bit ids it rejects) and dropped into `artifacts/`. At runtime this
//! module:
//!
//! 1. opens a [`xla::PjRtClient`] (CPU PJRT plugin);
//! 2. parses each artifact with `HloModuleProto::from_text_file`;
//! 3. compiles it into a cached executable;
//! 4. feeds it rust-owned buffers on the hot path — no Python anywhere.
//!
//! Submodules:
//! * [`literal`] — f64⇄f32 literal conversion helpers with shape checks;
//! * [`margin_exec`] — the batched blocked-margin kernel (L1 Pallas):
//!   per-block prefix margins for a whole batch in one call;
//! * [`pegasos_exec`] — the fused Pegasos update+projection step (L2);
//! * [`predict_exec`] — dense batched margin (MXU matmul path) for
//!   test-set evaluation.

pub mod literal;
pub mod margin_exec;
pub mod pegasos_exec;
pub mod predict_exec;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

/// Default artifact directory (relative to the repo root / CWD).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Shared PJRT client + executable cache.
///
/// Compilation is expensive (~ms–s); executables are cached by artifact
/// path and reused across calls. `Runtime` is cheaply clonable (Arc).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    cache: Arc<Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>>,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Open the CPU PJRT client with the default artifact directory.
    pub fn cpu() -> Result<Self> {
        Self::with_artifact_dir(ARTIFACT_DIR)
    }

    /// Open the CPU PJRT client rooted at `artifact_dir`.
    pub fn with_artifact_dir(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client: Arc::new(client),
            cache: Arc::new(Mutex::new(HashMap::new())),
            artifact_dir: artifact_dir.into(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The PJRT client (for advanced callers).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Resolve an artifact name (`"margin_b16.hlo.txt"`) to its path.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifact_dir.join(name)
    }

    /// Is the artifact present on disk?
    pub fn artifact_available(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let path = self.artifact_path(name);
        self.load_path(&path)
    }

    /// Load + compile an explicit path (cached).
    pub fn load_path(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        if !path.exists() {
            return Err(Error::MissingArtifact(path.to_path_buf()));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute a loaded artifact on literal inputs, returning the output
    /// literals (tuple outputs are decomposed — aot.py lowers everything
    /// with `return_tuple=True`).
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla("executable produced no output".into()))?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_opens() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::with_artifact_dir("/nonexistent-dir").unwrap();
        match rt.load("nope.hlo.txt") {
            Err(Error::MissingArtifact(p)) => {
                assert!(p.to_string_lossy().contains("nope.hlo.txt"))
            }
            other => panic!("expected MissingArtifact, got {:?}", other.map(|_| ())),
        }
        assert!(!rt.artifact_available("nope.hlo.txt"));
    }
}
