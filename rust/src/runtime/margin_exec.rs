//! Batched blocked-margin execution (the L1 Pallas kernel, from rust).
//!
//! Artifact contract (`artifacts/margin_b{BLOCK}.hlo.txt`, produced by
//! `python/compile/aot.py::export_margin`):
//!
//! ```text
//! inputs : w  f32[DIM]          — weight vector
//!          x  f32[BATCH, DIM]   — example batch (policy-ordered rows)
//!          y  f32[BATCH]        — signed labels
//! output : (prefix f32[BATCH, NBLOCKS],)
//!          prefix[b, k] = y[b] · Σ_{j < (k+1)·BLOCK} w[j]·x[b, j]
//! ```
//!
//! The kernel emits the *running signed margin at every block boundary*
//! for the whole batch in one pass; the coordinator applies the STST
//! boundary to the prefix rows ([`crate::margin::evaluator::BlockedEvaluator::decide_from_prefixes`])
//! — block-granular curtailment, the TPU adaptation of Algorithm 1.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::margin::evaluator::BlockedEvaluator;
use crate::stst::boundary::Boundary;

use super::literal::{mat_f32, to_vec_f64, vec_f32};
use super::Runtime;

/// Compiled-in artifact geometry (must match aot.py).
pub mod shapes {
    /// Feature dimensionality (28×28 digits).
    pub const DIM: usize = 784;
    /// Batch rows per kernel call.
    pub const BATCH: usize = 32;
    /// Features per block (⇒ 49 blocks).
    pub const BLOCK: usize = 16;
    /// Blocks per example.
    pub const NBLOCKS: usize = DIM / BLOCK;
}

/// Runs the blocked-margin artifact over example batches.
pub struct BlockedMarginExecutor {
    rt: Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
    evaluator: BlockedEvaluator,
}

impl BlockedMarginExecutor {
    /// Artifact file name for the compiled block size.
    pub fn artifact_name() -> String {
        format!("margin_b{}.hlo.txt", shapes::BLOCK)
    }

    /// Load and compile the artifact (errors with `MissingArtifact` if
    /// `make artifacts` has not been run).
    pub fn new(rt: &Runtime) -> Result<Self> {
        let exe = rt.load(&Self::artifact_name())?;
        Ok(Self { rt: rt.clone(), exe, evaluator: BlockedEvaluator::new(shapes::BLOCK) })
    }

    /// Compute the signed prefix-margin matrix for up to [`shapes::BATCH`]
    /// examples (rows padded with zeros). Returns one `NBLOCKS`-vector per
    /// input example.
    pub fn prefixes(
        &self,
        w: &[f64],
        examples: &[&[f64]],
        labels: &[f64],
    ) -> Result<Vec<Vec<f64>>> {
        if w.len() != shapes::DIM {
            return Err(Error::DimMismatch {
                expected: shapes::DIM,
                got: w.len(),
                context: "margin_exec weights".into(),
            });
        }
        if examples.len() != labels.len() {
            return Err(Error::Config(format!(
                "{} examples but {} labels",
                examples.len(),
                labels.len()
            )));
        }
        if examples.len() > shapes::BATCH {
            return Err(Error::Config(format!(
                "batch {} exceeds compiled batch {}",
                examples.len(),
                shapes::BATCH
            )));
        }
        let mut xbuf = vec![0.0f64; shapes::BATCH * shapes::DIM];
        for (i, ex) in examples.iter().enumerate() {
            if ex.len() != shapes::DIM {
                return Err(Error::DimMismatch {
                    expected: shapes::DIM,
                    got: ex.len(),
                    context: format!("margin_exec example {i}"),
                });
            }
            xbuf[i * shapes::DIM..(i + 1) * shapes::DIM].copy_from_slice(ex);
        }
        let mut ybuf = vec![0.0f64; shapes::BATCH];
        ybuf[..labels.len()].copy_from_slice(labels);

        let outputs = self.rt.execute(
            &self.exe,
            &[vec_f32(w), mat_f32(&xbuf, shapes::BATCH, shapes::DIM)?, vec_f32(&ybuf)],
        )?;
        let prefix = outputs
            .first()
            .ok_or_else(|| Error::Xla("margin artifact returned empty tuple".into()))?;
        let flat = to_vec_f64(prefix, shapes::BATCH * shapes::NBLOCKS)?;
        Ok((0..examples.len())
            .map(|i| flat[i * shapes::NBLOCKS..(i + 1) * shapes::NBLOCKS].to_vec())
            .collect())
    }

    /// Full batched sequential decision: run the kernel, then apply the
    /// boundary to each prefix row. Returns per-example
    /// `(features_charged, early_stopped, margin_at_stop)`.
    pub fn decide<B: Boundary + ?Sized>(
        &self,
        w: &[f64],
        examples: &[&[f64]],
        labels: &[f64],
        theta: f64,
        var_sn: &[f64],
        boundary: &B,
    ) -> Result<Vec<(usize, bool, f64)>> {
        let rows = self.prefixes(w, examples, labels)?;
        Ok(rows
            .iter()
            .zip(var_sn)
            .map(|(row, &v)| {
                self.evaluator.decide_from_prefixes(row, shapes::DIM, theta, v, boundary)
            })
            .collect())
    }

    /// The block-granular evaluator this executor mirrors (tests use it
    /// to cross-check native vs XLA decisions).
    pub fn evaluator(&self) -> &BlockedEvaluator {
        &self.evaluator
    }
}

#[cfg(test)]
mod tests {
    //! Pure shape/validation tests; numeric agreement with the native
    //! evaluator lives in `rust/tests/integration_runtime.rs` (needs
    //! `make artifacts`).
    use super::*;

    #[test]
    fn artifact_name_encodes_block() {
        assert_eq!(BlockedMarginExecutor::artifact_name(), "margin_b16.hlo.txt");
        assert_eq!(shapes::NBLOCKS * shapes::BLOCK, shapes::DIM);
    }

    #[test]
    fn missing_artifact_surfaces_cleanly() {
        let rt = Runtime::with_artifact_dir("/definitely-missing").unwrap();
        match BlockedMarginExecutor::new(&rt) {
            Err(Error::MissingArtifact(_)) => {}
            other => panic!("expected MissingArtifact, got {:?}", other.err()),
        }
    }
}
