//! Literal conversion helpers (f64 host data ⇄ f32 XLA literals).
//!
//! The learners keep f64 for numerically robust online statistics; the
//! artifacts are compiled for f32 (the TPU-native compute type per the
//! hardware adaptation). These helpers centralize the down/up-casts and
//! shape plumbing with hard dimension checks.

use crate::error::{Error, Result};

/// Build a 1-D f32 literal from f64 data.
pub fn vec_f32(data: &[f64]) -> xla::Literal {
    let f: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    xla::Literal::vec1(&f)
}

/// Build a rank-2 f32 literal `[rows, cols]` from row-major f64 data.
pub fn mat_f32(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        return Err(Error::DimMismatch {
            expected: rows * cols,
            got: data.len(),
            context: "mat_f32".into(),
        });
    }
    let f: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    Ok(xla::Literal::vec1(&f).reshape(&[rows as i64, cols as i64])?)
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f64) -> xla::Literal {
    xla::Literal::scalar(v as f32)
}

/// Extract an f32 literal into f64s, checking the element count.
pub fn to_vec_f64(lit: &xla::Literal, expect: usize) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit.to_vec()?;
    if v.len() != expect {
        return Err(Error::DimMismatch {
            expected: expect,
            got: v.len(),
            context: "to_vec_f64".into(),
        });
    }
    Ok(v.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_round_trip() {
        let lit = vec_f32(&[1.0, -2.5, 3.25]);
        let back = to_vec_f64(&lit, 3).unwrap();
        assert_eq!(back, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn mat_shape_checked() {
        assert!(mat_f32(&[1.0; 6], 2, 3).is_ok());
        assert!(mat_f32(&[1.0; 5], 2, 3).is_err());
    }

    #[test]
    fn extract_count_checked() {
        let lit = vec_f32(&[1.0, 2.0]);
        assert!(to_vec_f64(&lit, 3).is_err());
    }

    #[test]
    fn scalar_builds() {
        let s = scalar_f32(0.5);
        let v: f32 = s.get_first_element().unwrap();
        assert_eq!(v, 0.5);
    }
}
