//! CSV / JSON export of metrics and curves.
//!
//! The bench harness regenerates every paper figure as a CSV the plots
//! (and EXPERIMENTS.md tables) are built from. Writers are tolerant of
//! ragged curve sets and always emit a header.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};

use super::curve::Curve;

/// Write a set of curves as long-format CSV: `series,x,y`.
pub fn curves_to_csv(curves: &[Curve], path: &Path) -> Result<()> {
    let f = File::create(path).map_err(|e| Error::io(path, e))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "series,x,y").map_err(|e| Error::io(path, e))?;
    for c in curves {
        for (x, y) in c.xs.iter().zip(&c.ys) {
            writeln!(w, "{},{},{}", c.name, x, y).map_err(|e| Error::io(path, e))?;
        }
    }
    Ok(())
}

/// Render curves as a long-format CSV string (for stdout reporting).
pub fn curves_to_csv_string(curves: &[Curve]) -> String {
    let mut s = String::from("series,x,y\n");
    for c in curves {
        for (x, y) in c.xs.iter().zip(&c.ys) {
            s.push_str(&format!("{},{},{}\n", c.name, x, y));
        }
    }
    s
}

/// Write a [`Json`] document as pretty JSON.
pub fn to_json_file(value: &crate::util::json::Json, path: &Path) -> Result<()> {
    std::fs::write(path, value.to_string_pretty()).map_err(|e| Error::io(path, e))
}

/// A fixed-width console table builder for bench output (mirrors the
/// rows the paper's figures display).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate().take(width.len()) {
                line.push_str(&format!("{:<w$}  ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_round_shape() {
        let mut c = Curve::new("s1");
        c.push(1.0, 2.0);
        c.push(3.0, 4.0);
        let s = curves_to_csv_string(&[c]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "series,x,y");
        assert_eq!(lines[1], "s1,1,2");
        assert_eq!(lines[2], "s1,3,4");
    }

    #[test]
    fn csv_file_write() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let p = dir.path().join("c.csv");
        let mut c = Curve::new("x");
        c.push(0.0, 1.0);
        curves_to_csv(&[c], &p).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.contains("x,0,1"));
    }

    #[test]
    fn json_file_write() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let p = dir.path().join("m.json");
        let m = crate::metrics::TrainingMetrics::new();
        to_json_file(&m.to_json(), &p).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.contains("\"examples\": 0"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "features"]);
        t.row(&["attentive".into(), "49.2".into()]);
        t.row(&["full".into(), "784".into()]);
        let s = t.render();
        assert!(s.contains("algo"));
        assert!(s.lines().count() == 4);
        // all data lines start at the same column for the 2nd field
        let l1 = s.lines().nth(2).unwrap();
        let l2 = s.lines().nth(3).unwrap();
        assert_eq!(l1.find("49.2").map(|i| i > 9), Some(true));
        assert!(l2.starts_with("full"));
    }
}
