//! Learning curves: (x, y) series with fixed-budget checkpointing.
//!
//! The paper's middle/right subfigures plot error versus examples seen.
//! [`Curve`] records points and supports averaging several runs at shared
//! x-positions (the paper averages 10 permutations).


/// A named (x, y) series.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    /// Series name (e.g. "attentive/test-error").
    pub name: String,
    /// X values (e.g. examples seen).
    pub xs: Vec<f64>,
    /// Y values.
    pub ys: Vec<f64>,
}

impl Curve {
    /// Empty named curve.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), xs: Vec::new(), ys: Vec::new() }
    }

    /// Append a point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.xs.push(x);
        self.ys.push(y);
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Is the curve empty?
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Last y value, if any.
    pub fn last_y(&self) -> Option<f64> {
        self.ys.last().copied()
    }

    /// Pointwise mean of several curves sharing x-positions. Curves of
    /// different lengths are averaged over their common prefix.
    pub fn mean(name: impl Into<String>, curves: &[Curve]) -> Curve {
        let mut out = Curve::new(name);
        if curves.is_empty() {
            return out;
        }
        let len = curves.iter().map(|c| c.len()).min().unwrap_or(0);
        for i in 0..len {
            let x = curves[0].xs[i];
            let y = curves.iter().map(|c| c.ys[i]).sum::<f64>() / curves.len() as f64;
            out.push(x, y);
        }
        out
    }

    /// Pointwise standard deviation across runs (for error bars).
    pub fn std(name: impl Into<String>, curves: &[Curve]) -> Curve {
        let mut out = Curve::new(name);
        if curves.is_empty() {
            return out;
        }
        let len = curves.iter().map(|c| c.len()).min().unwrap_or(0);
        for i in 0..len {
            let mean = curves.iter().map(|c| c.ys[i]).sum::<f64>() / curves.len() as f64;
            let var = curves.iter().map(|c| (c.ys[i] - mean).powi(2)).sum::<f64>()
                / curves.len() as f64;
            out.push(curves[0].xs[i], var.sqrt());
        }
        out
    }
}

/// Decides when to take curve checkpoints: every `every` examples.
#[derive(Debug, Clone, Copy)]
pub struct Checkpointer {
    /// Checkpoint period in examples.
    pub every: u64,
}

impl Checkpointer {
    /// Checkpoint every `every` examples (min 1).
    pub fn new(every: u64) -> Self {
        Self { every: every.max(1) }
    }

    /// Should we checkpoint after `examples` consumed?
    #[inline]
    pub fn due(&self, examples: u64) -> bool {
        examples % self.every == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_query() {
        let mut c = Curve::new("t");
        c.push(1.0, 0.5);
        c.push(2.0, 0.25);
        assert_eq!(c.len(), 2);
        assert_eq!(c.last_y(), Some(0.25));
    }

    #[test]
    fn mean_and_std_across_runs() {
        let mut a = Curve::new("a");
        let mut b = Curve::new("b");
        for i in 0..5 {
            a.push(i as f64, 1.0);
            b.push(i as f64, 3.0);
        }
        b.push(5.0, 9.0); // extra point ignored (common prefix)
        let m = Curve::mean("m", &[a.clone(), b.clone()]);
        assert_eq!(m.len(), 5);
        assert!(m.ys.iter().all(|&y| (y - 2.0).abs() < 1e-12));
        let s = Curve::std("s", &[a, b]);
        assert!(s.ys.iter().all(|&y| (y - 1.0).abs() < 1e-12));
    }

    #[test]
    fn mean_of_none_is_empty() {
        assert!(Curve::mean("m", &[]).is_empty());
    }

    #[test]
    fn checkpointer_period() {
        let c = Checkpointer::new(100);
        assert!(c.due(100));
        assert!(c.due(200));
        assert!(!c.due(150));
        assert!(Checkpointer::new(0).due(1)); // clamped to 1
    }
}
