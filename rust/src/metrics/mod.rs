//! Metrics: counters, learning curves, feature-cost accounting, export.
//!
//! The paper's figures are all derived from three streams: features
//! evaluated per example, generalization error over the training stream,
//! and prediction error under early stopping. [`TrainingMetrics`]
//! accumulates them with constant-time updates on the hot path;
//! [`curve::Curve`] down-samples to fixed checkpoints; [`export`] writes
//! CSV/JSON rows the bench harness and plots consume.

pub mod curve;
pub mod export;


use crate::stst::decision::DecisionAudit;

/// Rolling metrics for one training run.
#[derive(Debug, Clone, Default)]
pub struct TrainingMetrics {
    /// Examples consumed.
    pub examples: u64,
    /// Total feature evaluations spent.
    pub features_evaluated: u64,
    /// Feature evaluations a full-computation learner would have spent
    /// (`examples × dim`; the denominator of the savings ratio).
    pub features_full: u64,
    /// Model updates performed.
    pub updates: u64,
    /// Examples skipped via early stop.
    pub early_stops: u64,
    /// Online mistakes (sign errors at evaluation time, before update).
    pub online_mistakes: u64,
    /// Decision-error audit (populated when auditing is on).
    pub audit: DecisionAudit,
}

impl TrainingMetrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one consumed example.
    #[inline]
    pub fn record_example(
        &mut self,
        dim: usize,
        evaluated: usize,
        updated: bool,
        early_stopped: bool,
        mistake: bool,
    ) {
        self.examples += 1;
        self.features_evaluated += evaluated as u64;
        self.features_full += dim as u64;
        if updated {
            self.updates += 1;
        }
        if early_stopped {
            self.early_stops += 1;
        }
        if mistake {
            self.online_mistakes += 1;
        }
    }

    /// Average features evaluated per example.
    pub fn avg_features(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.features_evaluated as f64 / self.examples as f64
        }
    }

    /// Computation-saving factor vs. full evaluation (the paper's "15×").
    pub fn speedup(&self) -> f64 {
        if self.features_evaluated == 0 {
            1.0
        } else {
            self.features_full as f64 / self.features_evaluated as f64
        }
    }

    /// Early-stop rate over examples.
    pub fn early_stop_rate(&self) -> f64 {
        if self.examples == 0 { 0.0 } else { self.early_stops as f64 / self.examples as f64 }
    }

    /// Online mistake rate.
    pub fn online_error(&self) -> f64 {
        if self.examples == 0 { 0.0 } else { self.online_mistakes as f64 / self.examples as f64 }
    }

    /// Serialize to a [`crate::util::json::Json`] object.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj([
            ("examples", Json::Num(self.examples as f64)),
            ("features_evaluated", Json::Num(self.features_evaluated as f64)),
            ("features_full", Json::Num(self.features_full as f64)),
            ("updates", Json::Num(self.updates as f64)),
            ("early_stops", Json::Num(self.early_stops as f64)),
            ("online_mistakes", Json::Num(self.online_mistakes as f64)),
            ("avg_features", Json::Num(self.avg_features())),
            ("speedup", Json::Num(self.speedup())),
            ("decision_error_rate", Json::Num(self.audit.conditional_error_rate())),
        ])
    }

    /// Merge a shard (parallel training).
    pub fn merge(&mut self, other: &TrainingMetrics) {
        self.examples += other.examples;
        self.features_evaluated += other.features_evaluated;
        self.features_full += other.features_full;
        self.updates += other.updates;
        self.early_stops += other.early_stops;
        self.online_mistakes += other.online_mistakes;
        self.audit.merge(&other.audit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let mut m = TrainingMetrics::new();
        m.record_example(784, 49, false, true, false);
        m.record_example(784, 784, true, false, true);
        assert_eq!(m.examples, 2);
        assert!((m.avg_features() - 416.5).abs() < 1e-12);
        assert!((m.speedup() - 1568.0 / 833.0).abs() < 1e-12);
        assert!((m.early_stop_rate() - 0.5).abs() < 1e-12);
        assert!((m.online_error() - 0.5).abs() < 1e-12);
        assert_eq!(m.updates, 1);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = TrainingMetrics::new();
        assert_eq!(m.avg_features(), 0.0);
        assert_eq!(m.speedup(), 1.0);
        assert_eq!(m.online_error(), 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = TrainingMetrics::new();
        a.record_example(10, 5, true, false, false);
        let mut b = TrainingMetrics::new();
        b.record_example(10, 10, false, true, true);
        a.merge(&b);
        assert_eq!(a.examples, 2);
        assert_eq!(a.features_evaluated, 15);
        assert_eq!(a.early_stops, 1);
        assert_eq!(a.online_mistakes, 1);
    }
}
