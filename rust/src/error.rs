//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. The variants
//! are deliberately coarse: callers almost always either surface the error
//! to the CLI or convert it into a metric; fine-grained matching is only
//! needed for the runtime (artifact-missing) and data (format) paths.

use std::path::PathBuf;

/// Crate result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Unified error for the attentive crate.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// An I/O failure, annotated with the path involved when known.
    #[error("io error on {path:?}: {source}")]
    Io {
        /// Offending path (best effort).
        path: PathBuf,
        /// Underlying error.
        #[source]
        source: std::io::Error,
    },

    /// A dataset or artifact file had an invalid format.
    #[error("format error in {what}: {detail}")]
    Format {
        /// What was being parsed (e.g. "idx header", "libsvm line 17").
        what: String,
        /// Human-readable detail.
        detail: String,
    },

    /// The requested AOT artifact is missing; run `make artifacts`.
    #[error("missing AOT artifact {0:?}; run `make artifacts` first")]
    MissingArtifact(PathBuf),

    /// An error bubbled up from the XLA/PJRT runtime.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Invalid configuration or arguments.
    #[error("invalid config: {0}")]
    Config(String),

    /// Dimension mismatch between model and data.
    #[error("dimension mismatch: expected {expected}, got {got} ({context})")]
    DimMismatch {
        /// Expected dimensionality.
        expected: usize,
        /// Observed dimensionality.
        got: usize,
        /// Where the mismatch happened.
        context: String,
    },

    /// A label or class was requested that the dataset does not contain.
    #[error("unknown class {0}")]
    UnknownClass(i64),
}

impl Error {
    /// Helper: wrap an `std::io::Error` with its path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Helper: format error.
    pub fn format(what: impl Into<String>, detail: impl Into<String>) -> Self {
        Error::Format { what: what.into(), detail: detail.into() }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_mentions_artifact_path() {
        let e = Error::MissingArtifact(PathBuf::from("artifacts/margin.hlo.txt"));
        let s = e.to_string();
        assert!(s.contains("artifacts/margin.hlo.txt"));
        assert!(s.contains("make artifacts"));
    }

    #[test]
    fn dim_mismatch_reports_both_sides() {
        let e = Error::DimMismatch { expected: 784, got: 64, context: "margin".into() };
        let s = e.to_string();
        assert!(s.contains("784") && s.contains("64"));
    }
}
