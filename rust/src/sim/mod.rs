//! Random-walk simulation — reproduces Figure 2.
//!
//! * [`walks`] — generators of weighted bounded random walks with
//!   controllable drift (the `(w_i, X_i)` processes of §3.1).
//! * [`bridge`] — Figure 2(a): empirical decision-error rates of the
//!   Constant STST versus the Brownian-bridge closed form, across δ and n.
//! * [`stopping`] — Figure 2(b): empirical expected stopping times versus
//!   the Theorem 2 `O(√n)` law.

pub mod bridge;
pub mod stopping;
pub mod walks;

pub use bridge::{BridgePoint, simulate_decision_errors};
pub use stopping::{StoppingPoint, simulate_stopping_times};
pub use walks::WalkGenerator;
