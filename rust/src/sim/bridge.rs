//! Figure 2(a): empirical decision-error rate vs. Brownian-bridge theory.
//!
//! For each `(n, δ)` cell we draw many walks, run the Constant STST with
//! level `τ(δ, var(S_n))` against threshold θ, finish every stopped walk
//! out-of-band (the audit), and report the empirical conditional
//! decision-error rate `P(stopped | S_n < θ)` next to the theoretical δ.
//! The paper's claim: "the boundary behaves similarly to what's expected
//! from theory".


use crate::stst::boundary::{Boundary, ConstantBoundary, StopContext};
use crate::stst::decision::{DecisionAudit, EvalOutcome};

use super::walks::{WalkGenerator, WeightProfile};

/// One cell of the Figure 2(a) grid.
#[derive(Debug, Clone)]
pub struct BridgePoint {
    /// Walk length.
    pub n: usize,
    /// Target decision-error rate.
    pub delta: f64,
    /// Decision threshold θ.
    pub theta: f64,
    /// Empirical conditional error rate `P(stop before n | S_n < θ)`.
    pub empirical: f64,
    /// Number of "important" walks (`S_n < θ`) observed — the
    /// conditioning set size; governs the error bars.
    pub important: u64,
    /// Empirical unconditional stop rate (computation saving).
    pub stop_rate: f64,
    /// Mean stopping time over stopped walks.
    pub mean_stop_time: f64,
}

/// Simulation parameters for the Figure 2(a) sweep.
#[derive(Debug, Clone)]
pub struct BridgeSimConfig {
    /// Walks per (n, δ) cell.
    pub walks_per_cell: usize,
    /// Drift of the increments (must be > 0 per the theory's
    /// rare-event assumption; smaller drift ⇒ more important walks).
    pub drift: f64,
    /// Uniform noise half-width.
    pub spread: f64,
    /// Decision threshold θ.
    pub theta: f64,
    /// Weight profile.
    pub profile: WeightProfile,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for BridgeSimConfig {
    fn default() -> Self {
        Self {
            walks_per_cell: 20_000,
            drift: 0.02,
            spread: 0.9,
            theta: 0.0,
            profile: WeightProfile::Uniform,
            seed: 0xB51D_6E,
        }
    }
}

/// Run one `(n, δ)` cell: returns the empirical rates.
pub fn simulate_cell(cfg: &BridgeSimConfig, n: usize, delta: f64) -> BridgePoint {
    let boundary = ConstantBoundary::new(delta);
    let mut gen = WalkGenerator::new(
        cfg.seed ^ (n as u64) << 20 ^ (delta.to_bits().rotate_left(17)),
        cfg.drift,
        cfg.spread,
        cfg.profile,
    );
    let var_sn = gen.sum_variance(n);
    let ctx = StopContext { evaluated: 0, total: n, theta: cfg.theta, var_sn };
    let tau = boundary.level(&ctx); // constant: independent of i

    let mut audit = DecisionAudit::new();
    let mut stop_times: u64 = 0;
    let mut stops: u64 = 0;
    for _ in 0..cfg.walks_per_cell {
        let inc = gen.draw(n);
        // Walk the prefix; record first crossing of theta + tau.
        let mut s = 0.0;
        let mut stopped_at: Option<usize> = None;
        for (i, &d) in inc.iter().enumerate() {
            s += d;
            if stopped_at.is_none() && s >= cfg.theta + tau && i + 1 < n {
                stopped_at = Some(i + 1);
                // keep summing: the audit needs the full sum
            }
        }
        let important = s < cfg.theta;
        match (stopped_at, important) {
            (Some(t), true) => {
                audit.record(EvalOutcome::StoppedBelow);
                stop_times += t as u64;
                stops += 1;
            }
            (Some(t), false) => {
                audit.record(EvalOutcome::StoppedAbove);
                stop_times += t as u64;
                stops += 1;
            }
            (None, true) => audit.record(EvalOutcome::FullBelow),
            (None, false) => audit.record(EvalOutcome::FullAbove),
        }
    }
    BridgePoint {
        n,
        delta,
        theta: cfg.theta,
        empirical: audit.conditional_error_rate(),
        important: audit.important(),
        stop_rate: audit.stop_rate(),
        mean_stop_time: if stops == 0 { n as f64 } else { stop_times as f64 / stops as f64 },
    }
}

/// Full Figure 2(a) sweep over `ns × deltas` (parallel over cells).
pub fn simulate_decision_errors(
    cfg: &BridgeSimConfig,
    ns: &[usize],
    deltas: &[f64],
) -> Vec<BridgePoint> {
    let cells: Vec<(usize, f64)> =
        ns.iter().flat_map(|&n| deltas.iter().map(move |&d| (n, d))).collect();
    crate::util::parallel::par_map(&cells, |&(n, d)| simulate_cell(cfg, n, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_error_tracks_delta() {
        // The conditional error rate should be within a small factor of δ
        // (the bridge approximation is asymptotic; generous tolerance).
        let cfg = BridgeSimConfig { walks_per_cell: 8_000, ..Default::default() };
        for delta in [0.05, 0.1, 0.3] {
            let p = simulate_cell(&cfg, 512, delta);
            assert!(
                p.empirical < 2.5 * delta + 0.02,
                "delta={delta}: empirical {} way above target",
                p.empirical
            );
            // and the test is not vacuous: it must actually stop walks
            assert!(p.stop_rate > 0.3, "delta={delta}: stop rate {}", p.stop_rate);
        }
    }

    #[test]
    fn stricter_delta_fewer_errors() {
        let cfg = BridgeSimConfig { walks_per_cell: 8_000, ..Default::default() };
        let strict = simulate_cell(&cfg, 512, 0.01);
        let lax = simulate_cell(&cfg, 512, 0.4);
        assert!(strict.empirical <= lax.empirical + 0.02);
        assert!(strict.mean_stop_time > lax.mean_stop_time);
    }

    #[test]
    fn sweep_covers_grid() {
        let cfg = BridgeSimConfig { walks_per_cell: 500, ..Default::default() };
        let pts = simulate_decision_errors(&cfg, &[64, 128], &[0.1, 0.2, 0.3]);
        assert_eq!(pts.len(), 6);
        assert!(pts.iter().any(|p| p.n == 64 && p.delta == 0.3));
    }
}
