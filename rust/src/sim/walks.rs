//! Weighted bounded random-walk generation for boundary validation.
//!
//! The STST theory is stated for `S_n = Σ w_i X_i` with `X_i ∈ [−1, 1]`.
//! [`WalkGenerator`] draws such processes with a chosen drift `E[X]` and
//! weight profile, deterministic per seed, and exposes exactly the
//! quantities the boundary needs (`var(S_n)` under independence).

use crate::util::rng::Rng64;

/// Weight profiles for the simulated walks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightProfile {
    /// All weights 1 (classic random walk).
    Uniform,
    /// Weights decay as `1/sqrt(i+1)` (heavy-head, like a sorted |w|).
    Decaying,
    /// Weights alternate 0.5 / 1.5 (mild heterogeneity).
    Alternating,
}

impl WeightProfile {
    /// Materialize the profile at dimensionality `n`.
    pub fn weights(self, n: usize) -> Vec<f64> {
        match self {
            WeightProfile::Uniform => vec![1.0; n],
            WeightProfile::Decaying => {
                (0..n).map(|i| 1.0 / ((i + 1) as f64).sqrt()).collect()
            }
            WeightProfile::Alternating => {
                (0..n).map(|i| if i % 2 == 0 { 0.5 } else { 1.5 }).collect()
            }
        }
    }
}

/// Generator of bounded-increment walks `X_i ∈ [−1,1]` with `E[X] = drift`.
///
/// Increments are drawn as `X = clamp(drift + U, −1, 1)` where `U` is
/// uniform on `[−spread, spread]`; for `|drift| + spread ≤ 1` no clamping
/// occurs and the moments are exact: `E[X] = drift`,
/// `var(X) = spread²/3`.
#[derive(Debug, Clone)]
pub struct WalkGenerator {
    rng: Rng64,
    /// Mean increment `E[X]`.
    pub drift: f64,
    /// Half-width of the uniform noise.
    pub spread: f64,
    /// Weight profile applied to increments.
    pub profile: WeightProfile,
}

impl WalkGenerator {
    /// New generator; panics unless `|drift| + spread ≤ 1` so the
    /// `X_i ∈ [−1,1]` requirement holds without clamping.
    pub fn new(seed: u64, drift: f64, spread: f64, profile: WeightProfile) -> Self {
        assert!(
            drift.abs() + spread <= 1.0 + 1e-12,
            "|drift| + spread must be <= 1 (got {drift} + {spread})"
        );
        assert!(spread > 0.0, "spread must be positive");
        Self { rng: Rng64::seed_from_u64(seed), drift, spread, profile }
    }

    /// Per-increment variance `var(X) = spread²/3`.
    pub fn increment_variance(&self) -> f64 {
        self.spread * self.spread / 3.0
    }

    /// Exact `var(S_n) = Σ w_i² var(X)` for walks of length `n`.
    pub fn sum_variance(&self, n: usize) -> f64 {
        let vx = self.increment_variance();
        self.profile.weights(n).iter().map(|w| w * w * vx).sum()
    }

    /// Draw one walk of length `n`; returns the weighted increments
    /// `w_i·X_i` (so partial sums are plain prefixes).
    pub fn draw(&mut self, n: usize) -> Vec<f64> {
        let ws = self.profile.weights(n);
        (0..n)
            .map(|i| {
                let x = self.drift + self.rng.range_f64(-self.spread, self.spread);
                ws[i] * x
            })
            .collect()
    }

    /// Draw a walk and return `(increments, full_sum)`.
    pub fn draw_with_sum(&mut self, n: usize) -> (Vec<f64>, f64) {
        let inc = self.draw(n);
        let s = inc.iter().sum();
        (inc, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_match_theory() {
        let mut g = WalkGenerator::new(0, 0.2, 0.5, WeightProfile::Uniform);
        let n = 2000;
        let mut mean = 0.0;
        let mut var = 0.0;
        let samples = 200;
        for _ in 0..samples {
            let (_, s) = g.draw_with_sum(n);
            mean += s / samples as f64;
        }
        // re-draw for variance around theoretical mean n*drift
        let tmean = n as f64 * 0.2;
        for _ in 0..samples {
            let (_, s) = g.draw_with_sum(n);
            var += (s - tmean) * (s - tmean) / samples as f64;
        }
        assert!((mean - tmean).abs() < 0.05 * tmean, "mean {mean} vs {tmean}");
        let tvar = g.sum_variance(n);
        assert!((var - tvar).abs() < 0.35 * tvar, "var {var} vs {tvar}");
    }

    #[test]
    fn increments_bounded() {
        let mut g = WalkGenerator::new(1, 0.3, 0.7, WeightProfile::Uniform);
        for x in g.draw(5000) {
            assert!((-1.0..=1.0).contains(&x), "increment {x} out of bounds");
        }
    }

    #[test]
    fn profiles_shape_variance() {
        let g = WalkGenerator::new(0, 0.1, 0.5, WeightProfile::Decaying);
        let u = WalkGenerator::new(0, 0.1, 0.5, WeightProfile::Uniform);
        // Decaying weights give strictly less total variance than uniform.
        assert!(g.sum_variance(100) < u.sum_variance(100));
        // Alternating: sum w² = n/2*(0.25+2.25)/... check concrete value
        let a = WalkGenerator::new(0, 0.1, 0.5, WeightProfile::Alternating);
        let expected = (50.0 * 0.25 + 50.0 * 2.25) * a.increment_variance();
        assert!((a.sum_variance(100) - expected).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WalkGenerator::new(9, 0.1, 0.5, WeightProfile::Uniform).draw(50);
        let b = WalkGenerator::new(9, 0.1, 0.5, WeightProfile::Uniform).draw(50);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "must be <= 1")]
    fn rejects_unbounded_increments() {
        WalkGenerator::new(0, 0.8, 0.5, WeightProfile::Uniform);
    }
}
