//! Figure 2(b): expected stopping time grows as O(√n) (Theorem 2).
//!
//! For a sweep of walk lengths `n`, draw positive-drift walks, run the
//! Constant STST level, record the first crossing time, and compare the
//! empirical mean stopping time to (a) the Wald bound
//! `(τ + k)/E[X]` and (b) a fitted `c·√n` law.


use crate::stst::boundary::{Boundary, ConstantBoundary, StopContext};
use crate::stst::wald;

use super::walks::{WalkGenerator, WeightProfile};

/// One point of the Figure 2(b) curve.
#[derive(Debug, Clone)]
pub struct StoppingPoint {
    /// Walk length (number of available features).
    pub n: usize,
    /// Empirical mean stopping time (capped at n for non-crossing walks).
    pub mean_stop: f64,
    /// Std-dev of the stopping time.
    pub std_stop: f64,
    /// Fraction of walks that crossed before n.
    pub crossed_frac: f64,
    /// Theorem 2 upper bound `(τ + k)/E[X]`.
    pub wald_bound: f64,
    /// Empirical Wald-identity gap `|E[S_T] − E[T]·E[X]| / |E[S_T]|`
    /// over crossing walks.
    pub wald_gap: f64,
}

/// Configuration for the stopping-time sweep.
#[derive(Debug, Clone)]
pub struct StoppingSimConfig {
    /// Walks per n.
    pub walks_per_n: usize,
    /// Drift `E[X] > 0`.
    pub drift: f64,
    /// Uniform half-width.
    pub spread: f64,
    /// δ of the Constant STST.
    pub delta: f64,
    /// Base seed.
    pub seed: u64,
}

impl Default for StoppingSimConfig {
    fn default() -> Self {
        Self { walks_per_n: 5_000, drift: 0.1, spread: 0.8, delta: 0.1, seed: 0x57_0B }
    }
}

/// Simulate mean stopping times for each `n` (parallel over n).
pub fn simulate_stopping_times(cfg: &StoppingSimConfig, ns: &[usize]) -> Vec<StoppingPoint> {
    crate::util::parallel::par_map(ns, |&n| simulate_one(cfg, n))
}

fn simulate_one(cfg: &StoppingSimConfig, n: usize) -> StoppingPoint {
    let boundary = ConstantBoundary::new(cfg.delta);
    let mut gen = WalkGenerator::new(
        cfg.seed ^ (n as u64).rotate_left(13),
        cfg.drift,
        cfg.spread,
        WeightProfile::Uniform,
    );
    let var_sn = gen.sum_variance(n);
    let tau =
        boundary.level(&StopContext { evaluated: 0, total: n, theta: 0.0, var_sn });

    let mut times = Vec::with_capacity(cfg.walks_per_n);
    let mut sums_at_stop = Vec::new();
    let mut times_crossing = Vec::new();
    let mut crossed = 0usize;
    for _ in 0..cfg.walks_per_n {
        let inc = gen.draw(n);
        let mut s = 0.0;
        let mut t = n;
        for (i, &d) in inc.iter().enumerate() {
            s += d;
            if s >= tau {
                t = i + 1;
                crossed += 1;
                sums_at_stop.push(s);
                times_crossing.push(t as f64);
                break;
            }
        }
        times.push(t as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
    StoppingPoint {
        n,
        mean_stop: mean,
        std_stop: var.sqrt(),
        crossed_frac: crossed as f64 / cfg.walks_per_n as f64,
        wald_bound: wald::expected_stopping_time_bound(var_sn, cfg.delta, 1.0, cfg.drift),
        wald_gap: wald::wald_identity_gap(&times_crossing, &sums_at_stop, cfg.drift),
    }
}

/// Fit `mean_stop ≈ c·√n` over the sweep; returns `(c, r²)`.
pub fn fit_sqrt(points: &[StoppingPoint]) -> (f64, f64) {
    let ns: Vec<f64> = points.iter().map(|p| p.n as f64).collect();
    let ts: Vec<f64> = points.iter().map(|p| p.mean_stop).collect();
    wald::fit_sqrt_law(&ns, &ts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> StoppingSimConfig {
        StoppingSimConfig { walks_per_n: 1_500, ..Default::default() }
    }

    #[test]
    fn stopping_time_is_sublinear_sqrt_like() {
        let pts = simulate_stopping_times(&quick_cfg(), &[256, 1024, 4096]);
        // Quadrupling n should roughly double the stopping time (sqrt law),
        // certainly not quadruple it.
        let t0 = pts[0].mean_stop;
        let t2 = pts[2].mean_stop;
        let ratio = t2 / t0; // n grew 16x; sqrt law predicts 4x
        assert!(ratio < 8.0, "stopping time ratio {ratio} too close to linear");
        assert!(ratio > 2.0, "stopping time ratio {ratio} implausibly flat");
        let (c, r2) = fit_sqrt(&pts);
        assert!(c > 0.0);
        assert!(r2 > 0.95, "sqrt fit r2 {r2}");
    }

    #[test]
    fn bound_dominates_empirical_mean() {
        let pts = simulate_stopping_times(&quick_cfg(), &[512, 2048]);
        for p in &pts {
            assert!(
                p.mean_stop <= p.wald_bound * 1.05,
                "n={}: mean {} exceeds Wald bound {}",
                p.n,
                p.mean_stop,
                p.wald_bound
            );
        }
    }

    #[test]
    fn most_walks_cross_under_positive_drift() {
        let pts = simulate_stopping_times(&quick_cfg(), &[1024]);
        assert!(pts[0].crossed_frac > 0.9, "crossed {}", pts[0].crossed_frac);
    }

    #[test]
    fn wald_identity_approximately_holds() {
        // Overshoot makes E[S_T] slightly exceed E[T]·E[X]; the relative
        // gap should still be small for long walks.
        let pts = simulate_stopping_times(&quick_cfg(), &[4096]);
        assert!(pts[0].wald_gap < 0.2, "wald gap {}", pts[0].wald_gap);
    }
}
