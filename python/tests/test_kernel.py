"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, block sizes, and value ranges; every case
asserts allclose between the interpret-mode Pallas kernel and ref.py.
"""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.partial_margin import BATCH_TILE, blocked_prefix_margin
from compile.kernels.pegasos_update import BLOCK as UPDATE_BLOCK
from compile.kernels.pegasos_update import dense_margins, pegasos_step
from compile.kernels.ref import (
    blocked_prefix_margin_ref,
    dense_margins_ref,
    pegasos_step_ref,
)

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _assert_close(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- margin


@st.composite
def margin_case(draw):
    block = draw(st.sampled_from([4, 8, 16, 49]))
    n_blocks = draw(st.integers(min_value=1, max_value=12))
    dim = block * n_blocks
    batch_tiles = draw(st.integers(min_value=1, max_value=3))
    batch = BATCH_TILE * batch_tiles
    elems = st.floats(
        min_value=-2.0, max_value=2.0, allow_nan=False, allow_infinity=False, width=32
    )
    w = draw(hnp.arrays(np.float32, (dim,), elements=elems))
    x = draw(hnp.arrays(np.float32, (batch, dim), elements=elems))
    y = draw(
        hnp.arrays(np.float32, (batch,), elements=st.sampled_from([-1.0, 1.0]))
    )
    return block, w, x, y


@hypothesis.given(margin_case())
def test_blocked_prefix_margin_matches_ref(case):
    block, w, x, y = case
    got = blocked_prefix_margin(w, x, y, block=block)
    want = blocked_prefix_margin_ref(w, x, y, block=block)
    assert got.shape == (x.shape[0], x.shape[1] // block)
    _assert_close(got, want)


def test_margin_final_column_is_full_margin():
    rng = np.random.RandomState(0)
    w = rng.randn(784).astype(np.float32)
    x = rng.rand(32, 784).astype(np.float32)
    y = np.where(np.arange(32) % 2 == 0, 1.0, -1.0).astype(np.float32)
    prefix = blocked_prefix_margin(w, x, y, block=16)
    full = y * (x @ w)
    _assert_close(prefix[:, -1], full, rtol=1e-4, atol=1e-4)


def test_margin_prefix_monotone_structure():
    # prefix[:, k] - prefix[:, k-1] must equal block k's signed sum.
    rng = np.random.RandomState(1)
    w = rng.randn(64).astype(np.float32)
    x = rng.randn(8, 64).astype(np.float32)
    y = np.ones(8, dtype=np.float32)
    prefix = np.asarray(blocked_prefix_margin(w, x, y, block=8))
    wx = x * w[None, :]
    per_block = wx.reshape(8, 8, 8).sum(axis=2)
    _assert_close(np.diff(prefix, axis=1), per_block[:, 1:], rtol=1e-4, atol=1e-4)


def test_margin_rejects_bad_shapes():
    w = jnp.zeros(64, jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)
    y = jnp.ones(8, jnp.float32)
    with pytest.raises(ValueError, match="must divide"):
        blocked_prefix_margin(w, x, y, block=7)
    with pytest.raises(ValueError, match="multiple"):
        blocked_prefix_margin(w, x[:5], y[:5], block=8)


# ---------------------------------------------------------------- update


@st.composite
def update_case(draw):
    dim = UPDATE_BLOCK * draw(st.integers(min_value=1, max_value=4))
    elems = st.floats(
        min_value=-1.0, max_value=1.0, allow_nan=False, allow_infinity=False, width=32
    )
    w = draw(hnp.arrays(np.float32, (dim,), elements=elems))
    x = draw(hnp.arrays(np.float32, (dim,), elements=elems))
    y = draw(st.sampled_from([-1.0, 1.0]))
    t = draw(st.integers(min_value=1, max_value=10_000))
    lam = draw(st.sampled_from([1e-4, 1e-3, 1e-2, 0.5]))
    return w, x, np.float32(y), np.float32(t), np.float32(lam)


@hypothesis.given(update_case())
def test_pegasos_step_matches_ref(case):
    w, x, y, t, lam = case
    got = pegasos_step(w, x, y, t, lam)
    want = pegasos_step_ref(w, x, y, t, lam)
    _assert_close(got, want, rtol=1e-4, atol=1e-5)


@hypothesis.given(update_case())
def test_pegasos_step_respects_ball(case):
    w, x, y, t, lam = case
    out = np.asarray(pegasos_step(w, x, y, t, lam))
    norm = np.linalg.norm(out)
    assert norm <= 1.0 / np.sqrt(lam) * (1.0 + 1e-4)


def test_pegasos_first_step_erases_history():
    # t = 1: decay = 0, so the old weights must not matter.
    dim = UPDATE_BLOCK
    w1 = np.ones(dim, dtype=np.float32) * 5
    w2 = -np.ones(dim, dtype=np.float32) * 3
    x = np.random.RandomState(2).rand(dim).astype(np.float32)
    a = pegasos_step(w1, x, np.float32(1), np.float32(1), np.float32(0.01))
    b = pegasos_step(w2, x, np.float32(1), np.float32(1), np.float32(0.01))
    _assert_close(a, b)


# --------------------------------------------------------------- predict


@hypothesis.given(
    hnp.arrays(
        np.float32,
        (16, 49),
        elements=st.floats(min_value=-1, max_value=1, width=32, allow_nan=False),
    )
)
def test_dense_margins_matches_ref(x):
    w = np.linspace(-1, 1, 49, dtype=np.float32)
    _assert_close(dense_margins(w, x), dense_margins_ref(w, x), rtol=1e-5, atol=1e-6)
