"""L2 model program shape/semantics checks + a numpy Pegasos cross-check."""

import numpy as np

from compile import model


def _rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


def test_margin_program_shapes():
    w = _rand((model.DIM,), 0)
    x = _rand((model.BATCH, model.DIM), 1)
    y = np.where(np.arange(model.BATCH) % 2 == 0, 1.0, -1.0).astype(np.float32)
    (prefix,) = model.margin_program(w, x, y)
    assert prefix.shape == (model.BATCH, model.N_BLOCKS)
    # geometry invariant shared with the rust runtime
    assert model.N_BLOCKS * model.BLOCK == model.DIM


def test_pegasos_step_program_matches_numpy():
    w = _rand((model.DIM,), 2) * 0.1
    x = _rand((model.DIM,), 3) * 0.5
    y, t, lam = np.float32(-1.0), np.float32(7.0), np.float32(1e-2)
    (w_new,) = model.pegasos_step_program(w, x, y, t, lam)
    mu = 1.0 / (lam * t)
    ref = (1.0 - 1.0 / t) * w + mu * y * x
    norm = np.linalg.norm(ref)
    limit = 1.0 / np.sqrt(lam)
    if norm > limit:
        ref = ref * (limit / norm)
    np.testing.assert_allclose(np.asarray(w_new), ref, rtol=1e-4, atol=1e-5)


def test_predict_program_is_matmul():
    w = _rand((model.DIM,), 4)
    x = _rand((model.BATCH, model.DIM), 5)
    (m,) = model.predict_program(w, x)
    np.testing.assert_allclose(np.asarray(m), x @ w, rtol=1e-4, atol=1e-4)


def test_margin_program_consistent_with_predict():
    # The final prefix column must equal y * predict margins.
    w = _rand((model.DIM,), 6)
    x = _rand((model.BATCH, model.DIM), 7)
    y = np.ones(model.BATCH, dtype=np.float32)
    (prefix,) = model.margin_program(w, x, y)
    (margins,) = model.predict_program(w, x)
    np.testing.assert_allclose(
        np.asarray(prefix[:, -1]), np.asarray(margins), rtol=1e-4, atol=1e-4
    )
