"""AOT lowering smoke tests: HLO text is produced, parseable-looking, and
matches the geometry contract the rust runtime assumes."""

import json

from compile import aot, model


def test_margin_export_produces_hlo_text():
    text = aot.export_margin()
    assert "HloModule" in text
    assert "ENTRY" in text
    # the margin program's output tuple: f32[32,49]
    assert f"f32[{model.BATCH},{model.N_BLOCKS}]" in text


def test_pegasos_export_produces_hlo_text():
    text = aot.export_pegasos_step()
    assert "HloModule" in text
    assert f"f32[{model.DIM}]" in text


def test_predict_export_produces_hlo_text():
    text = aot.export_predict()
    assert "HloModule" in text
    assert f"f32[{model.BATCH},{model.DIM}]" in text
    # a dot op must survive lowering (the MXU path)
    assert "dot(" in text or "dot." in text


def test_main_writes_all_artifacts(tmp_path):
    import sys
    import unittest.mock as mock

    argv = ["aot", "--out-dir", str(tmp_path)]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    names = {p.name for p in tmp_path.iterdir()}
    assert f"margin_b{model.BLOCK}.hlo.txt" in names
    assert "pegasos_step.hlo.txt" in names
    assert f"predict_b{model.BATCH}.hlo.txt" in names
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["dim"] == model.DIM
    assert len(manifest["artifacts"]) == 3
    for meta in manifest["artifacts"].values():
        assert meta["bytes"] > 100
        assert len(meta["sha256"]) == 64
