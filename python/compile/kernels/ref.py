"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package has a reference implementation here written
with nothing but ``jnp`` ops in the most obvious way possible. pytest
(python/tests/test_kernel.py) sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp


def blocked_prefix_margin_ref(w, x, y, *, block: int = 16):
    """Reference for kernels.partial_margin.blocked_prefix_margin."""
    batch, dim = x.shape
    n_blocks = dim // block
    wx = x * w[None, :]
    per_block = wx.reshape(batch, n_blocks, block).sum(axis=2)
    prefix = jnp.cumsum(per_block, axis=1)
    return y[:, None] * prefix


def pegasos_step_ref(w, x, y, t, lam):
    """Reference for kernels.pegasos_update.pegasos_step."""
    decay = 1.0 - 1.0 / t
    mu = 1.0 / (lam * t)
    wprime = decay * w + mu * y * x
    norm = jnp.sqrt(jnp.sum(wprime * wprime))
    limit = 1.0 / jnp.sqrt(lam)
    scale = jnp.minimum(1.0, limit / jnp.maximum(norm, 1e-30))
    return wprime * scale


def dense_margins_ref(w, x):
    """Reference for kernels.pegasos_update.dense_margins."""
    return jnp.einsum("bd,d->b", x, w)
