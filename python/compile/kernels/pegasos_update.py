"""L1 Pallas kernel: fused Pegasos update + projection.

One violating example triggers

    w' = (1 - 1/t) * w + (y / (lambda * t)) * x
    w_new = min(1, (1/sqrt(lambda)) / ||w'||) * w'

Fusing decay, axpy, norm, and rescale keeps the weight vector resident in
VMEM for the whole step (one HBM read of w/x, one write of w_new) instead
of the three passes an unfused implementation would make.

The norm reduction needs all blocks, so the kernel runs a two-phase grid:
phase 1 accumulates ``w'`` and its squared norm into scratch-free output
slots; a cheap jnp epilogue applies the scale (XLA fuses it with the
kernel output — verified in the lowered HLO).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature block per grid step (VPU lane multiples).
BLOCK = 196


def _update_kernel(w_ref, x_ref, y_ref, t_ref, lam_ref, wprime_ref):
    """w' = (1 - 1/t) * w + (y / (lam * t)) * x for one feature block."""
    t = t_ref[0]
    lam = lam_ref[0]
    decay = 1.0 - 1.0 / t
    mu = 1.0 / (lam * t)
    wprime_ref[...] = decay * w_ref[...] + (mu * y_ref[0]) * x_ref[...]


@jax.jit
def pegasos_step(w, x, y, t, lam):
    """Fused Pegasos SGD step with projection onto the 1/sqrt(lam) ball.

    Args:
      w: f32[dim] current weights.
      x: f32[dim] violating example.
      y: f32[] label (±1).
      t: f32[] update counter (>= 1).
      lam: f32[] regularization.

    Returns:
      f32[dim] updated, projected weights.
    """
    (dim,) = w.shape
    if dim % BLOCK != 0:
        raise ValueError(f"BLOCK {BLOCK} must divide dim {dim}")
    y1 = jnp.reshape(y, (1,))
    t1 = jnp.reshape(t, (1,))
    lam1 = jnp.reshape(lam, (1,))
    wprime = pl.pallas_call(
        _update_kernel,
        grid=(dim // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda k: (k,)),
            pl.BlockSpec((BLOCK,), lambda k: (k,)),
            pl.BlockSpec((1,), lambda k: (0,)),
            pl.BlockSpec((1,), lambda k: (0,)),
            pl.BlockSpec((1,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda k: (k,)),
        out_shape=jax.ShapeDtypeStruct((dim,), w.dtype),
        interpret=True,
    )(w, x, y1, t1, lam1)
    # Projection epilogue (fused by XLA into the same module).
    norm = jnp.sqrt(jnp.sum(wprime * wprime))
    limit = 1.0 / jnp.sqrt(lam)
    scale = jnp.minimum(1.0, limit / jnp.maximum(norm, 1e-30))
    return wprime * scale


@functools.partial(jax.jit, static_argnames=())
def dense_margins(w, x):
    """Dense batched margins ``x @ w`` — the MXU path for prediction."""
    return x @ w
