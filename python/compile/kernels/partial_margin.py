"""L1 Pallas kernel: blocked prefix-margin for a batch of examples.

The paper's hot-spot is the sequential evaluation of ``y * <w, x>``.
A scalar CPU walks features one by one; a TPU-shaped kernel instead keeps
a tile of examples VMEM-resident and emits the *running signed margin at
every block boundary* in one pass:

    prefix[b, k] = y[b] * sum_{j < (k+1)*BLOCK} w[j] * x[b, j]

The rust coordinator applies the STST boundary to the prefix rows
(block-granular curtailment — DESIGN.md §8).

Kernel geometry:
  grid = (batch // BATCH_TILE,)
  per step: x tile (BATCH_TILE, DIM) + w (DIM) live in VMEM
            (8 x 784 + 784 f32 ≈ 28 KiB — far under the ~16 MiB budget);
  compute: elementwise w*x on the VPU, block reduce, cumulative sum over
            blocks (a length-49 scan on an (8, 49) tile), sign by y.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU the same BlockSpec schedule lowers natively
(see DESIGN.md §Perf for the VMEM/MXU accounting).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch rows handled per kernel instance (f32 sublane count).
BATCH_TILE = 8


def _prefix_margin_kernel(n_blocks: int, block: int, w_ref, x_ref, y_ref, out_ref):
    """Compute all block-prefix margins for one batch tile."""
    bt = x_ref.shape[0]
    wx = x_ref[...] * w_ref[...][None, :]                  # (BT, DIM)  VPU
    per_block = wx.reshape(bt, n_blocks, block).sum(axis=2)  # (BT, NB)
    prefix = jnp.cumsum(per_block, axis=1)                 # (BT, NB) scan
    out_ref[...] = y_ref[...][:, None] * prefix


@functools.partial(jax.jit, static_argnames=("block",))
def blocked_prefix_margin(w, x, y, *, block: int = 16):
    """Signed prefix margins at block boundaries for a batch.

    Args:
      w: f32[dim] weight vector.
      x: f32[batch, dim] examples.
      y: f32[batch] signed labels (±1).
      block: features per block; must divide dim.

    Returns:
      f32[batch, dim // block] running signed margins; column k holds the
      margin after (k+1)*block features.
    """
    batch, dim = x.shape
    if dim % block != 0:
        raise ValueError(f"block {block} must divide dim {dim}")
    if batch % BATCH_TILE != 0:
        raise ValueError(f"batch {batch} must be a multiple of {BATCH_TILE}")
    n_blocks = dim // block
    kernel = functools.partial(_prefix_margin_kernel, n_blocks, block)
    return pl.pallas_call(
        kernel,
        grid=(batch // BATCH_TILE,),
        in_specs=[
            pl.BlockSpec((dim,), lambda b: (0,)),
            pl.BlockSpec((BATCH_TILE, dim), lambda b: (b, 0)),
            pl.BlockSpec((BATCH_TILE,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, n_blocks), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_blocks), x.dtype),
        interpret=True,
    )(w, x, y)
