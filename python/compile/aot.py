"""AOT export: lower the L2 programs to HLO TEXT for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example and
DESIGN.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emitted artifacts (names must match rust/src/runtime/*):
  margin_b16.hlo.txt    — margin_program   (w, x, y) -> (prefix,)
  pegasos_step.hlo.txt  — pegasos_step_program
  predict_b32.hlo.txt   — predict_program
  manifest.json         — shapes + sha256 of each artifact (for `make`
                          freshness checks and runtime diagnostics)
"""

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_margin() -> str:
    lowered = jax.jit(model.margin_program).lower(
        f32(model.DIM), f32(model.BATCH, model.DIM), f32(model.BATCH)
    )
    return to_hlo_text(lowered)


def export_pegasos_step() -> str:
    lowered = jax.jit(model.pegasos_step_program).lower(
        f32(model.DIM), f32(model.DIM), f32(), f32(), f32()
    )
    return to_hlo_text(lowered)


def export_predict() -> str:
    lowered = jax.jit(model.predict_program).lower(
        f32(model.DIM), f32(model.BATCH, model.DIM)
    )
    return to_hlo_text(lowered)


EXPORTS = {
    f"margin_b{model.BLOCK}.hlo.txt": export_margin,
    "pegasos_step.hlo.txt": export_pegasos_step,
    f"predict_b{model.BATCH}.hlo.txt": export_predict,
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", help="export a single artifact by name")
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "dim": model.DIM,
        "batch": model.BATCH,
        "block": model.BLOCK,
        "n_blocks": model.N_BLOCKS,
        "artifacts": {},
    }
    for name, export in EXPORTS.items():
        if args.only and name != args.only:
            continue
        text = export()
        path = out_dir / name
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()
        manifest["artifacts"][name] = {"sha256": digest, "bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars, sha256 {digest[:12]})")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
