"""L2: the jax compute graphs that get AOT-lowered for the rust runtime.

Three exported programs, all built on the L1 Pallas kernels:

* ``margin_program``       — batched blocked prefix margins (the attentive
                             filter's compute; kernels/partial_margin.py).
* ``pegasos_step_program`` — fused update + projection for one violating
                             example (kernels/pegasos_update.py).
* ``predict_program``      — dense batched margins (the MXU matmul path).

Shapes are fixed at export time (see aot.py); the rust side
(``rust/src/runtime/margin_exec.rs::shapes``) must agree.
"""

from compile.kernels.partial_margin import blocked_prefix_margin
from compile.kernels.pegasos_update import dense_margins, pegasos_step

# Geometry shared with rust/src/runtime/margin_exec.rs::shapes.
DIM = 784
BATCH = 32
BLOCK = 16
N_BLOCKS = DIM // BLOCK


def margin_program(w, x, y):
    """f32[DIM], f32[BATCH, DIM], f32[BATCH] -> (f32[BATCH, N_BLOCKS],)."""
    return (blocked_prefix_margin(w, x, y, block=BLOCK),)


def pegasos_step_program(w, x, y, t, lam):
    """f32[DIM] x f32[DIM] x scalars -> (f32[DIM],)."""
    return (pegasos_step(w, x, y, t, lam),)


def predict_program(w, x):
    """f32[DIM], f32[BATCH, DIM] -> (f32[BATCH],)."""
    return (dense_margins(w, x),)
