//! Bench/regeneration harness for paper Figure 4 — the hard pair.
//!
//! The paper's caption says "MNIST 3 vs 10"; MNIST has digits 0–9, so we
//! use the canonical hard pair (3, 8) — see DESIGN.md §7. The paper's
//! observation to reproduce: the hard pair needs more features on average
//! than the easy pair of Figure 3 (72 vs 49 in the paper), while
//! maintaining the same Attentive ≈ Full generalization and
//! Attentive > Budgeted early-prediction ordering.
//!
//! `cargo bench --bench fig4_mnist_3v8`

#[path = "fig3_mnist_2v3.rs"]
#[allow(dead_code)]
mod fig3;

fn main() {
    fig3::run_figure((3, 8), "fig4", "fig4.csv");
}
