//! Bench/regeneration harness for paper Figure 3 — digits 2 vs 3, δ=0.1.
//!
//! Regenerates all three subfigures' series: (left) average features per
//! example over the stream, (middle) generalization error curves,
//! (right) early-stopped prediction error — for Attentive (blue),
//! Budgeted at attentive's average (green), Full (red); 10-run averages.
//! Then times one full training pass per algorithm.
//!
//! `cargo bench --bench fig3_mnist_2v3` (set BENCH_QUICK=1 for CI scale)

use attentive::config::{DataConfig, ExperimentConfig};
use attentive::coordinator::scheduler::run_experiment;
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::coordinator::factory;
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::{curves_to_csv, Table};
use attentive::stst::boundary::AnyBoundary;
use attentive::util::bench::{black_box, Bench};

fn cfg(name: &str, pair: (i64, i64), count: usize, boundary: AnyBoundary, policy: CoordinatePolicy, runs: u64) -> ExperimentConfig {
    // Quick (CI) scale trains on less data, so it uses a larger λ to stay
    // in Pegasos's converged regime; full scale uses the paper-style
    // λ = 1e-4 over 5 epochs of 4k task examples.
    let quick = std::env::var("BENCH_QUICK").is_ok();
    ExperimentConfig {
        name: name.into(),
        data: DataConfig::Synth { seed: 7, count },
        pair,
        boundary,
        policy,
        lambda: if quick { 1e-3 } else { 1e-4 },
        epochs: 5,
        runs,
        eval_every: 400,
        ..ExperimentConfig::paper_default()
    }
}

pub fn run_figure(pair: (i64, i64), label: &str, csv: &str) {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (count, runs) = if quick { (4_000, 3) } else { (20_000, 10) };

    let att = run_experiment(&cfg(
        &format!("{label}-attentive"),
        pair,
        count,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        CoordinatePolicy::WeightSampled,
        runs,
    ))
    .unwrap();
    let k = att.avg_features.round().max(1.0) as usize;
    let bud = run_experiment(&cfg(
        &format!("{label}-budgeted(k={k})"),
        pair,
        count,
        AnyBoundary::Budgeted { k },
        CoordinatePolicy::Permuted,
        runs,
    ))
    .unwrap();
    let full = run_experiment(&cfg(
        &format!("{label}-full"),
        pair,
        count,
        AnyBoundary::Full,
        CoordinatePolicy::WeightSampled,
        runs,
    ))
    .unwrap();

    let mut t = Table::new(&[
        "algorithm",
        "avg feats",
        "speedup",
        "gen err",
        "early-pred err",
        "pred feats",
    ]);
    for o in [&att, &bud, &full] {
        t.row(&[
            o.name.clone(),
            format!("{:.1}", o.avg_features),
            format!("{:.1}x", o.speedup(784)),
            format!("{:.4}", o.final_test_error),
            format!("{:.4}", o.final_test_error_early),
            format!("{:.1}", o.predict_avg_features),
        ]);
    }
    println!("{label} — digits {} vs {} (runs = {runs})", pair.0, pair.1);
    println!("{}", t.render());

    // Paper-shape assertions: who wins, roughly by how much. Only
    // enforced at full scale — BENCH_QUICK trains on too little data for
    // λ=1e-3 Pegasos to reach the converged regime the shape needs.
    if !quick {
        assert!(att.avg_features < 784.0 / 3.0, "attentive should save ≥3x on training features");
        assert!(
            att.final_test_error <= full.final_test_error + 0.06,
            "attentive must approximately match full generalization \
             (measured gaps: fig3 -0.008, fig4 +0.039 at 10-run scale)"
        );
        assert!(
            att.final_test_error_early <= bud.final_test_error_early + 0.02,
            "attentive early prediction must beat/match budgeted"
        );
    }

    let mut curves = Vec::new();
    for o in [&att, &bud, &full] {
        curves.push(o.mean_features.clone());
        curves.push(o.mean_test_error.clone());
    }
    curves_to_csv(&curves, std::path::Path::new(csv)).unwrap();
    println!("series written to {csv}\n");

    // ---- timing: one end-to-end training pass per algorithm ----
    let mut bench = if quick { Bench::quick() } else { Bench::new() };
    for (name, boundary, policy) in [
        ("attentive", AnyBoundary::Constant { delta: 0.1, paper_literal: false }, CoordinatePolicy::WeightSampled),
        ("budgeted", AnyBoundary::Budgeted { k }, CoordinatePolicy::Permuted),
        ("full", AnyBoundary::Full, CoordinatePolicy::WeightSampled),
    ] {
        let c = cfg(name, pair, 4_000, boundary, policy, 1);
        let (train, _) = factory::build_task(&c).unwrap();
        let n = train.len() as f64;
        bench.measure_with_items(
            format!("{label}/train-1-epoch/{name} ({} ex)", train.len()),
            Some(n),
            || {
                let mut l = factory::build_learner(&c, train.dim(), 0);
                let trainer = Trainer::new(TrainerConfig {
                    epochs: 1,
                    eval_every: 0,
                    curves: false,
                    ..Default::default()
                });
                black_box(trainer.fit(l.as_mut(), &train));
            },
        );
    }
    bench.write_csv(std::path::Path::new(&format!("bench_{label}.csv"))).ok();
}

fn main() {
    run_figure((2, 3), "fig3", "fig3.csv");
}
