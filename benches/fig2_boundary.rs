//! Bench/regeneration harness for paper Figure 2.
//!
//! (a) empirical conditional decision-error rate of the Constant STST vs
//!     the Brownian-bridge closed form, across n and δ;
//! (b) mean stopping time vs n with the c·sqrt(n) fit and Wald bound.
//!
//! Prints the same series the figure plots, then times the simulator
//! cells with the in-tree bench harness. `cargo bench --bench fig2_boundary`

use attentive::metrics::export::Table;
use attentive::sim::bridge::{simulate_cell, simulate_decision_errors, BridgeSimConfig};
use attentive::sim::stopping::{fit_sqrt, simulate_stopping_times, StoppingSimConfig};
use attentive::util::bench::{black_box, Bench};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let walks = if quick { 4_000 } else { 30_000 };

    // ---------- Figure 2(a) ----------
    let cfg = BridgeSimConfig { walks_per_cell: walks, ..Default::default() };
    let ns = [256usize, 1024, 4096];
    let deltas = [0.01, 0.05, 0.1, 0.2, 0.3];
    let pts = simulate_decision_errors(&cfg, &ns, &deltas);
    let mut t = Table::new(&["n", "delta", "empirical err", "err/delta", "stop rate"]);
    let mut worst_ratio = 0.0f64;
    for p in &pts {
        worst_ratio = worst_ratio.max(p.empirical / p.delta);
        t.row(&[
            p.n.to_string(),
            format!("{:.3}", p.delta),
            format!("{:.4}", p.empirical),
            format!("{:.2}", p.empirical / p.delta),
            format!("{:.3}", p.stop_rate),
        ]);
    }
    println!("Figure 2(a) — decision errors vs theory (worst ratio {worst_ratio:.2})");
    println!("{}", t.render());

    // ---------- Figure 2(b) ----------
    let scfg = StoppingSimConfig {
        walks_per_n: if quick { 2_000 } else { 20_000 },
        ..Default::default()
    };
    let ns2 = [64usize, 128, 256, 512, 1024, 2048, 4096];
    let spts = simulate_stopping_times(&scfg, &ns2);
    let (c, r2) = fit_sqrt(&spts);
    let mut t2 = Table::new(&["n", "mean stop", "fit c*sqrt(n)", "wald bound"]);
    for p in &spts {
        t2.row(&[
            p.n.to_string(),
            format!("{:.1}", p.mean_stop),
            format!("{:.1}", c * (p.n as f64).sqrt()),
            format!("{:.1}", p.wald_bound),
        ]);
    }
    println!("Figure 2(b) — stopping times: E[T] ≈ {c:.2}·sqrt(n), R² = {r2:.4}");
    println!("{}", t2.render());
    assert!(r2 > 0.95, "sqrt law fit degraded: R² = {r2}");

    // ---------- Timing ----------
    let mut bench = if quick { Bench::quick() } else { Bench::new() };
    let tcfg = BridgeSimConfig { walks_per_cell: 2_000, ..Default::default() };
    bench.measure_with_items("fig2a/cell n=1024 δ=0.1 (2k walks)", Some(2_000.0), || {
        black_box(simulate_cell(&tcfg, 1024, 0.1));
    });
    let stcfg = StoppingSimConfig { walks_per_n: 2_000, ..Default::default() };
    bench.measure_with_items("fig2b/stopping n=1024 (2k walks)", Some(2_000.0), || {
        black_box(simulate_stopping_times(&stcfg, &[1024]));
    });
    bench.write_csv(std::path::Path::new("bench_fig2.csv")).ok();
}
