//! Microbenchmark for the serving score kernel: precomputed
//! stop-threshold tables vs the sqrt-laden closed forms, and the
//! blocked [`TabledPredictor`] vs the scalar [`EarlyStopPredictor`]
//! walk, on identical inputs.
//!
//! Equivalence is asserted — bit-identical `(score, evaluated)` — on
//! every example before anything is timed, so a speedup can never come
//! from diverging answers. Three comparisons:
//!
//! * `tau/*` — one stop-threshold read: [`Boundary::level`] (closed
//!   form, `sqrt`/`log` per call) vs [`BoundaryTable::level_at`] (one
//!   table read).
//! * `walk/*` — whole dense walks under the Constant and Curved STST:
//!   scalar per-feature walker vs the blocked LUT kernel.
//! * `walk/full` — the never-stopping baseline, isolating the pure
//!   blocked-multiply win with no boundary checks in either path.
//!
//! `cargo bench --bench score_kernel` (BENCH_QUICK=1 for CI scale);
//! writes `bench_score_kernel.csv`.

use attentive::learner::predictor::{EarlyStopPredictor, TabledPredictor};
use attentive::stst::boundary::{AnyBoundary, Boundary, BoundaryTable, StopContext};
use attentive::util::bench::{black_box, Bench};

const DIM: usize = 784;
const VAR_SN: f64 = 4.0;

/// Deterministic pseudo-random f64 in [-1, 1] (xorshift; no deps).
fn prng(state: &mut u64) -> f64 {
    *state ^= *state << 13;
    *state ^= *state >> 7;
    *state ^= *state << 17;
    (*state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let examples = if quick { 200 } else { 2_000 };

    // One weight vector, mixed traffic: even examples confidently
    // aligned with the weights (stop after a handful of coordinates —
    // serving's common case), odd examples small-signal (walk long).
    let mut seed = 0x0dd5_eed5_u64;
    let w: Vec<f64> = (0..DIM).map(|_| prng(&mut seed)).collect();
    let xs: Vec<Vec<f64>> = (0..examples)
        .map(|e| {
            (0..DIM)
                .map(|j| {
                    if e % 2 == 0 {
                        w[j].signum() * 0.5
                    } else {
                        prng(&mut seed) * 0.1
                    }
                })
                .collect()
        })
        .collect();
    let order: Vec<usize> = (0..DIM).collect();

    let constant = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
    let curved = AnyBoundary::Curved { delta: 0.1 };
    let full = AnyBoundary::Full;

    // Correctness gate before any timing: the blocked LUT kernel must
    // reproduce the scalar walker exactly on every example it is about
    // to be timed on.
    for boundary in [&constant, &curved, &full] {
        let table = BoundaryTable::for_boundary(boundary, VAR_SN, DIM);
        let scalar = EarlyStopPredictor::new(boundary);
        let tabled = TabledPredictor::new(&table);
        for x in &xs {
            assert_eq!(
                tabled.predict(&w, x, &order),
                scalar.predict(&w, x, &order, VAR_SN),
                "blocked kernel diverged ({})",
                boundary.name()
            );
        }
    }

    let mut bench = if quick { Bench::quick() } else { Bench::new() };

    // ---- One threshold read: closed form vs table ----
    let lookups = 100_000usize;
    let litems = Some(lookups as f64);
    let constant_table = BoundaryTable::for_boundary(&constant, VAR_SN, DIM);
    let curved_table = BoundaryTable::for_boundary(&curved, VAR_SN, DIM);
    bench.measure_with_items("tau/constant closed-form", litems, || {
        let mut acc = 0.0;
        for i in 0..lookups {
            let ctx =
                StopContext { evaluated: 1 + (i % (DIM - 1)), total: DIM, theta: 0.0, var_sn: VAR_SN };
            acc += constant.level(&ctx);
        }
        black_box(acc);
    });
    bench.measure_with_items("tau/constant table", litems, || {
        let mut acc = 0.0;
        for i in 0..lookups {
            acc += constant_table.level_at(1 + (i % (DIM - 1)));
        }
        black_box(acc);
    });
    bench.measure_with_items("tau/curved closed-form", litems, || {
        let mut acc = 0.0;
        for i in 0..lookups {
            let ctx =
                StopContext { evaluated: 1 + (i % (DIM - 1)), total: DIM, theta: 0.0, var_sn: VAR_SN };
            acc += curved.level(&ctx);
        }
        black_box(acc);
    });
    bench.measure_with_items("tau/curved table", litems, || {
        let mut acc = 0.0;
        for i in 0..lookups {
            acc += curved_table.level_at(1 + (i % (DIM - 1)));
        }
        black_box(acc);
    });

    // ---- Whole walks: scalar vs blocked LUT, per family ----
    let items = Some(examples as f64);
    for (name, boundary) in [("constant", &constant), ("curved", &curved), ("full", &full)] {
        let table = BoundaryTable::for_boundary(boundary, VAR_SN, DIM);
        let scalar = EarlyStopPredictor::new(boundary);
        bench.measure_with_items(format!("walk/{name} scalar"), items, || {
            let mut acc = 0.0;
            for x in &xs {
                acc += scalar.predict(&w, x, &order, VAR_SN).0;
            }
            black_box(acc);
        });
        let tabled = TabledPredictor::new(&table);
        bench.measure_with_items(format!("walk/{name} blocked-lut"), items, || {
            let mut acc = 0.0;
            for x in &xs {
                acc += tabled.predict(&w, x, &order).0;
            }
            black_box(acc);
        });
    }

    bench.write_csv(std::path::Path::new("bench_score_kernel.csv")).ok();
}
