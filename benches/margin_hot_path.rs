//! Hot-path microbenchmarks: the per-example margin machinery that
//! dominates training wall-clock, plus the native-vs-XLA batched margin
//! comparison (DESIGN.md §6, EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench margin_hot_path`

use attentive::data::synth::SynthDigits;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::pegasos::{Pegasos, PegasosConfig};
use attentive::learner::OnlineLearner;
use attentive::margin::evaluator::{BlockedEvaluator, ScalarEvaluator};
use attentive::margin::policy::{CoordinatePolicy, OrderGenerator};
use attentive::runtime::margin_exec::{shapes, BlockedMarginExecutor};
use attentive::runtime::Runtime;
use attentive::stst::boundary::{ConstantBoundary, TrivialBoundary};
use attentive::util::bench::{black_box, Bench};
use attentive::util::rng::Rng64;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut bench = if quick { Bench::quick() } else { Bench::new() };
    let dim = 784usize;
    let mut rng = Rng64::seed_from_u64(1);
    let w: Vec<f64> = (0..dim).map(|_| rng.range_f64(-0.1, 0.1)).collect();
    let mut gen = SynthDigits::new(2);
    let xs: Vec<Vec<f64>> = (0..64).map(|i| gen.render((i % 10) as u8)).collect();
    let order: Vec<usize> = (0..dim).collect();

    // ---- dense dot (the full-computation unit) -------------------------
    let mut i = 0;
    bench.measure_with_items("dot/784", Some(dim as f64), || {
        i = (i + 1) % xs.len();
        black_box(attentive::margin::dot(&w, &xs[i]));
    });

    // ---- scalar sequential walker under each boundary -------------------
    let scalar = ScalarEvaluator::new();
    let mut i = 0;
    bench.measure_with_items("walker/trivial (784 feats)", Some(dim as f64), || {
        i = (i + 1) % xs.len();
        black_box(scalar.evaluate(&w, &xs[i], 1.0, &order, 1.0, 0.05, &TrivialBoundary));
    });
    let cb = ConstantBoundary::new(0.1);
    let mut i = 0;
    bench.measure_with_items("walker/constant-stst", Some(dim as f64), || {
        i = (i + 1) % xs.len();
        black_box(scalar.evaluate(&w, &xs[i], 1.0, &order, 1.0, 0.05, &cb));
    });

    // ---- blocked evaluator (XLA-semantics, native) ----------------------
    let blocked = BlockedEvaluator::new(shapes::BLOCK);
    let mut i = 0;
    bench.measure_with_items("blocked-evaluator/constant-stst b=16", Some(dim as f64), || {
        i = (i + 1) % xs.len();
        black_box(blocked.evaluate(&w, &xs[i], 1.0, &order, 1.0, 0.05, &cb));
    });

    // ---- order generation (policy cost) ---------------------------------
    for policy in CoordinatePolicy::ALL {
        let mut g = OrderGenerator::new(policy, 3);
        g.refresh(&w);
        bench.measure(format!("policy/{}/next", policy.name()), || {
            black_box(g.next());
        });
    }

    // ---- end-to-end process() per example -------------------------------
    let stream: Vec<(Vec<f64>, f64)> = (0..256)
        .map(|i| (gen.render(if i % 2 == 0 { 2 } else { 3 }), if i % 2 == 0 { 1.0 } else { -1.0 }))
        .collect();
    {
        let mut full = Pegasos::full(dim, PegasosConfig { lambda: 1e-4, ..Default::default() });
        let mut i = 0;
        bench.measure_with_items("learner/full-pegasos/process", Some(1.0), || {
            i = (i + 1) % stream.len();
            black_box(full.process(&stream[i].0, stream[i].1));
        });
    }
    {
        let mut att = attentive_pegasos(dim, 1e-4, 0.1);
        // warm the model so early stopping is active (the steady state).
        for (x, y) in &stream {
            att.process(x, *y);
        }
        let mut i = 0;
        bench.measure_with_items("learner/attentive-pegasos/process (warm)", Some(1.0), || {
            i = (i + 1) % stream.len();
            black_box(att.process(&stream[i].0, stream[i].1));
        });
    }

    // ---- XLA batched margin artifact vs native batch --------------------
    match Runtime::cpu() {
        Ok(rt) if rt.artifact_available(&BlockedMarginExecutor::artifact_name()) => {
            let exec = BlockedMarginExecutor::new(&rt).expect("compile");
            let batch: Vec<&[f64]> = xs.iter().take(shapes::BATCH).map(|v| v.as_slice()).collect();
            let ys = vec![1.0; shapes::BATCH];
            bench.measure_with_items(
                format!("xla/margin-artifact batch={}", shapes::BATCH),
                Some(shapes::BATCH as f64),
                || {
                    black_box(exec.prefixes(&w, &batch, &ys).expect("exec"));
                },
            );
            let mut native_out = vec![0.0f64; shapes::BATCH];
            bench.measure_with_items(
                format!("native/dense-margin batch={}", shapes::BATCH),
                Some(shapes::BATCH as f64),
                || {
                    for (o, x) in native_out.iter_mut().zip(batch.iter()) {
                        *o = attentive::margin::dot(&w, x);
                    }
                    black_box(&native_out);
                },
            );
        }
        _ => println!("artifacts/ absent — skipping XLA margin timing"),
    }

    bench.write_csv(std::path::Path::new("bench_hot_path.csv")).ok();
}
