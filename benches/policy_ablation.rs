//! Ablation harness for paper §4.1's coordinate-selection policies plus
//! the design choices DESIGN.md calls out:
//!
//!   * policy × algorithm grid (sorted / weight-sampled / permuted ×
//!     attentive / budgeted / full) — the paper's experimental matrix;
//!   * Constant vs Curved STST (error-spending vs curtailed);
//!   * corrected eq. (8) root vs the paper-literal eq. (10) boundary;
//!   * δ sweep (computation/accuracy trade-off).
//!
//! `cargo bench --bench policy_ablation` (BENCH_QUICK=1 for CI scale)

use attentive::config::{DataConfig, ExperimentConfig};
use attentive::coordinator::scheduler::run_experiment;
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::Table;
use attentive::stst::boundary::AnyBoundary;

fn cfg(name: String, boundary: AnyBoundary, policy: CoordinatePolicy, count: usize, runs: u64) -> ExperimentConfig {
    ExperimentConfig {
        name,
        data: DataConfig::Synth { seed: 7, count },
        pair: (2, 3),
        boundary,
        policy,
        lambda: if std::env::var("BENCH_QUICK").is_ok() { 1e-3 } else { 1e-4 },
        epochs: 5,
        runs,
        eval_every: 0,
        ..ExperimentConfig::paper_default()
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (count, runs) = if quick { (3_000, 2 ) } else { (12_000, 6) };

    // ---- policy × algorithm grid (paper §4.1) --------------------------
    println!("=== policy × algorithm grid (digits 2v3, δ=0.1) ===");
    let mut t = Table::new(&["algorithm", "policy", "avg feats", "gen err", "early-pred err"]);
    let mut attentive_sorted_feats = f64::NAN;
    let mut attentive_permuted_feats = f64::NAN;
    for policy in [
        CoordinatePolicy::SortedByWeight,
        CoordinatePolicy::WeightSampled,
        CoordinatePolicy::Permuted,
    ] {
        let att = run_experiment(&cfg(
            format!("att-{}", policy.name()),
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy,
            count,
            runs,
        ))
        .unwrap();
        if policy == CoordinatePolicy::SortedByWeight {
            attentive_sorted_feats = att.avg_features;
        }
        if policy == CoordinatePolicy::Permuted {
            attentive_permuted_feats = att.avg_features;
        }
        t.row(&[
            "attentive".into(),
            policy.name().into(),
            format!("{:.1}", att.avg_features),
            format!("{:.4}", att.final_test_error),
            format!("{:.4}", att.final_test_error_early),
        ]);
        // Budgeted: impossible with sorted (paper), run the other two.
        if policy != CoordinatePolicy::SortedByWeight {
            let k = att.avg_features.round().max(1.0) as usize;
            let bud = run_experiment(&cfg(
                format!("bud-{}", policy.name()),
                AnyBoundary::Budgeted { k },
                policy,
                count,
                runs,
            ))
            .unwrap();
            t.row(&[
                format!("budgeted(k={k})"),
                policy.name().into(),
                format!("{:.1}", bud.avg_features),
                format!("{:.4}", bud.final_test_error),
                format!("{:.4}", bud.final_test_error_early),
            ]);
        }
    }
    let full = run_experiment(&cfg(
        "full".into(),
        AnyBoundary::Full,
        CoordinatePolicy::Sequential,
        count,
        runs,
    ))
    .unwrap();
    t.row(&[
        "full".into(),
        "sequential".into(),
        format!("{:.1}", full.avg_features),
        format!("{:.4}", full.final_test_error),
        format!("{:.4}", full.final_test_error_early),
    ]);
    println!("{}", t.render());
    println!(
        "sorted-by-|w| front-loads evidence: {:.1} feats vs permuted {:.1}\n",
        attentive_sorted_feats, attentive_permuted_feats
    );

    // ---- Constant vs Curved STST ---------------------------------------
    println!("=== boundary family ablation ===");
    let mut t2 = Table::new(&["boundary", "avg feats", "gen err", "early stops/ex"]);
    for (name, b) in [
        ("constant (eq. 8 root)", AnyBoundary::Constant { delta: 0.1, paper_literal: false }),
        ("constant (paper eq. 10)", AnyBoundary::Constant { delta: 0.1, paper_literal: true }),
        ("curved (curtailed)", AnyBoundary::Curved { delta: 0.1 }),
        ("full", AnyBoundary::Full),
    ] {
        let out = run_experiment(&cfg(
            format!("b-{name}"),
            b,
            CoordinatePolicy::WeightSampled,
            count,
            runs,
        ))
        .unwrap();
        let stops: f64 = out
            .runs
            .iter()
            .map(|r| r.metrics.early_stop_rate())
            .sum::<f64>()
            / out.runs.len().max(1) as f64;
        t2.row(&[
            name.into(),
            format!("{:.1}", out.avg_features),
            format!("{:.4}", out.final_test_error),
            format!("{:.3}", stops),
        ]);
    }
    println!("{}", t2.render());

    // ---- δ sweep --------------------------------------------------------
    println!("=== delta sweep (computation vs decision-error budget) ===");
    let mut t3 = Table::new(&["delta", "avg feats", "speedup", "gen err", "early-pred err"]);
    for delta in [0.01, 0.05, 0.1, 0.2, 0.4] {
        let out = run_experiment(&cfg(
            format!("d{delta}"),
            AnyBoundary::Constant { delta, paper_literal: false },
            CoordinatePolicy::WeightSampled,
            count,
            runs,
        ))
        .unwrap();
        t3.row(&[
            format!("{delta}"),
            format!("{:.1}", out.avg_features),
            format!("{:.1}x", out.speedup(784)),
            format!("{:.4}", out.final_test_error),
            format!("{:.4}", out.final_test_error_early),
        ]);
    }
    println!("{}", t3.render());
}
