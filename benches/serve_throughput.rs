//! Serving throughput over loopback TCP: wire protocol v1 vs v2 and
//! attentive early-exit vs full evaluation on identical traffic.
//!
//! Spawns the TCP front-end on an ephemeral port and drives it with the
//! load-generator client (mixed clean/noisy digit traffic, pipelined
//! connections) over each wire mode — v1 dense JSON lines, the v2
//! sparse JSON form, v2 binary frames, and the same examples packed
//! into v6 `SCORE_BATCH` frames — then hot-reloads the same
//! weights under the Full boundary via the control channel and replays
//! the identical stream. The attentive-vs-full gap is the paper's
//! focus-of-attention measured at the wire; the v1-vs-v2 gap is the
//! transport catching up with the evaluator (JSON parse of 784 dense
//! floats was the per-request bottleneck). A final multiclass pass
//! drives the all-pairs ensemble shard with native binary `classify`
//! frames, reporting per-voter feature cost — the paper's attention
//! mechanism compounding across `C(C-1)/2` voters.
//!
//! Writes the machine-readable `BENCH_serve.json` (override the path
//! with `BENCH_JSON=...`) consumed by CI's bench-smoke gate.
//!
//! `cargo bench --bench serve_throughput` (BENCH_QUICK=1 for CI scale)

use attentive::config::{IoBackend, ServerConfig};
use attentive::coordinator::service::{EnsembleSnapshot, ModelSnapshot};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::stream::ShuffledIndices;
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::multiclass::OneVsOneEnsemble;
use attentive::learner::pegasos::PegasosConfig;
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::{to_json_file, Table};
use attentive::server::loadgen::{self, Client, ClientMode, LoadGenConfig, LoadReport};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: f64 = 784.0;
/// Digit classes behind the multiclass classify scenario (3 classes →
/// 3 voters; enough to show per-voter compounding at CI scale).
const ENSEMBLE_CLASSES: [i64; 3] = [1, 2, 3];

fn train_snapshot(count: usize) -> ModelSnapshot {
    let ds = SynthDigits::new(7).generate_classes(count, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).expect("task");
    let mut learner = attentive_pegasos(task.dim(), 1e-4, 0.1);
    Trainer::new(TrainerConfig { epochs: 3, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    ModelSnapshot::from_trained(
        &mut learner,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        CoordinatePolicy::Permuted,
    )
}

fn train_ensemble(count: usize) -> EnsembleSnapshot {
    let ds = SynthDigits::new(13).generate_classes(count, &[1, 2, 3]);
    let boundary = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
    let cfg = PegasosConfig { lambda: 1e-2, seed: 13, ..Default::default() };
    let mut ensemble =
        OneVsOneEnsemble::new(ds.dim(), &ENSEMBLE_CLASSES, cfg, boundary.clone())
            .expect("ensemble");
    let shuffle = ShuffledIndices::new(ds.len(), 13);
    for epoch in 0..2 {
        ensemble.train_pass(&ds, &shuffle.epoch(epoch));
    }
    EnsembleSnapshot::from_trained(&mut ensemble, boundary, CoordinatePolicy::Permuted)
}

fn row(table: &mut Table, name: &str, r: &LoadReport) {
    // The `< DIM` early-exit heuristic only makes sense for single-voter
    // score traffic; classify counts are summed across voters (and the
    // payload is sparse), so the column would be meaningless there.
    let early = if r.total_voters > 0 || r.features.is_empty() {
        "-".to_string()
    } else {
        let rate = r.features.iter().filter(|&&f| (f as f64) < DIM).count() as f64
            / r.features.len() as f64;
        format!("{rate:.3}")
    };
    table.row(&[
        name.into(),
        format!("{:.0}", r.req_per_s()),
        format!("{:.1}", r.avg_features()),
        format!("{}", r.feature_percentile(0.50)),
        format!("{}", r.feature_percentile(0.99)),
        format!("{:.0}", r.bytes_per_req()),
        early,
        format!("{}", r.overloaded),
    ]);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (train_count, requests) = if quick { (2_000, 2_000) } else { (6_000, 10_000) };

    let attentive_snapshot = train_snapshot(train_count);
    let mut full_snapshot = attentive_snapshot.clone();
    full_snapshot.boundary = AnyBoundary::Full;
    let ensemble_snapshot = train_ensemble(train_count.min(3_000));
    let voters = ensemble_snapshot.voter_count();

    let srv_cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        max_batch: 16,
        queue: 4096,
        // Pin the backend: this bench's threads-vs-event-loop delta is
        // the regression signal, so neither side may drift with the
        // ATTENTIVE_IO_BACKEND env parameterization.
        io_backend: IoBackend::Threads,
        ..Default::default()
    };
    // One port, two shards: the binary 2-vs-3 model (default) and the
    // all-pairs ensemble behind the `digits` route.
    let server = TcpServer::serve_models(
        &srv_cfg,
        vec![
            ("default".to_string(), attentive_snapshot.clone().into()),
            ("digits".to_string(), ensemble_snapshot.clone().into()),
        ],
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();
    println!(
        "loopback serving bench on {addr}: {requests} requests/pass, 8 connections, pipeline 16"
    );

    let loadcfg = |mode: ClientMode| LoadGenConfig {
        addr: addr.clone(),
        connections: 8,
        requests,
        pipeline: 16,
        hard_fraction: 0.5,
        mode,
        sparse_eps: 0.05,
        seed: 11, // same seed every pass -> identical traffic
        ..Default::default()
    };

    let mut table = Table::new(&[
        "serving",
        "req/s",
        "avg feats",
        "p50",
        "p99",
        "B/req",
        "early-exit",
        "shed",
    ]);

    // Pass 1-3: the three wire modes against the attentive model.
    let mut passes: Vec<(String, LoadReport)> = Vec::new();
    for mode in ClientMode::ALL {
        let report = loadgen::run(&loadcfg(mode)).expect(mode.name());
        assert_eq!(
            report.answered + report.overloaded,
            requests as u64,
            "every request answered ({})",
            mode.name()
        );
        row(&mut table, &format!("attentive/{}", mode.name()), &report);
        passes.push((mode.name().to_string(), report));
    }

    // Pass 4: the identical example stream packed 16 per `SCORE_BATCH`
    // frame — one queue slot and one worker wakeup per frame. Batch
    // tallies count per example, so dividing by the v2-binary pass's
    // req/s reads off the batching speedup directly.
    let batch = loadgen::run(&LoadGenConfig {
        mode: ClientMode::Batch,
        batch_size: 16,
        ..loadcfg(ClientMode::Batch)
    })
    .expect("batch pass");
    assert_eq!(
        batch.answered + batch.overloaded,
        requests as u64,
        "every batched example answered"
    );
    row(&mut table, "attentive/batch", &batch);

    // Pass 5: multiclass classify against the ensemble shard — native
    // v3 binary frames, ensemble-class digit traffic.
    let classify = loadgen::run(&LoadGenConfig {
        mode: ClientMode::Classify,
        model: Some("digits".to_string()),
        digits: ENSEMBLE_CLASSES.iter().map(|&c| c as u8).collect(),
        ..loadcfg(ClientMode::Classify)
    })
    .expect("classify pass");
    assert_eq!(
        classify.answered + classify.overloaded,
        requests as u64,
        "every classify answered"
    );
    row(&mut table, "classify/v3-binary", &classify);

    // Pass 6: full evaluation over v1-dense (the attention baseline).
    let mut control = Client::connect(&addr).expect("control channel");
    control.reload(&full_snapshot).expect("hot reload to full evaluation");
    let full = loadgen::run(&loadcfg(ClientMode::V1Dense)).expect("full pass");
    assert_eq!(full.answered + full.overloaded, requests as u64, "every request answered");
    row(&mut table, "full/v1-dense", &full);

    println!("{}", table.render());
    let stats = control.stats().expect("stats");
    drop(control);
    server.shutdown();

    println!(
        "server totals: {} served, {} batches, early-exit rate {:.3}, {} reload(s)",
        stats.served, stats.batches, stats.early_exit_rate, stats.reloads
    );
    if classify.answered > 0 {
        println!(
            "multiclass: {} voters/request, {:.1} features/request total, \
             {:.1} features/voter (vs {:.0} dense per voter) — attention compounds \
             across the all-pairs vote",
            voters,
            classify.avg_features(),
            classify.avg_features_per_voter(),
            DIM,
        );
    }
    let v1 = &passes[0].1;
    let v2b = &passes[2].1;
    if v1.req_per_s() > 0.0 && v1.avg_features() > 0.0 {
        println!(
            "wire: v2-binary {:.0} req/s vs v1-dense {:.0} req/s ({:.1}x) at {:.0} vs {:.0} \
             request bytes; attention: {:.1} vs {:.1} features/request ({:.1}x saving)",
            v2b.req_per_s(),
            v1.req_per_s(),
            v2b.req_per_s() / v1.req_per_s(),
            v2b.bytes_per_req(),
            v1.bytes_per_req(),
            v1.avg_features(),
            full.avg_features(),
            full.avg_features() / v1.avg_features(),
        );
    }

    if v2b.req_per_s() > 0.0 {
        println!(
            "batch: {:.0} examples/s vs {:.0} singles/s over v2-binary ({:.2}x) \
             at 16 examples per SCORE_BATCH frame",
            batch.req_per_s(),
            v2b.req_per_s(),
            batch.req_per_s() / v2b.req_per_s(),
        );
    }

    passes.push(("batch".to_string(), batch));
    passes.push(("classify".to_string(), classify));
    passes.push(("full-v1-dense".to_string(), full));

    // Backend comparison: the identical wire-mode sweep against a fresh
    // server running the epoll event loop, at a connection count where
    // the thread backend's per-connection thread pairs start to hurt.
    // The delta lands in BENCH_serve.json (`event-loop/<mode>` rows and
    // the ratio), which is what docs/PERFORMANCE.md tracks.
    let mut event_ratio: Option<f64> = None;
    if cfg!(target_os = "linux") {
        let conns = if quick { 16 } else { 64 };
        let mut table2 = Table::new(&[
            "backend",
            "req/s",
            "avg feats",
            "p50",
            "p99",
            "B/req",
            "early-exit",
            "shed",
        ]);
        // Fresh servers for both sides: the original server's default
        // shard was hot-reloaded to full evaluation above, so neither
        // backend may reuse it.
        let event_cfg = ServerConfig {
            io_backend: IoBackend::EventLoop,
            event_threads: 4,
            ..srv_cfg.clone()
        };
        let event_server = TcpServer::serve_models(
            &event_cfg,
            vec![
                ("default".to_string(), attentive_snapshot.clone().into()),
                ("digits".to_string(), ensemble_snapshot.clone().into()),
            ],
        )
        .expect("bind loopback (event loop)");
        let event_addr = event_server.local_addr().to_string();
        println!(
            "event-loop pass on {event_addr}: {requests} requests/pass, {conns} connections"
        );
        for mode in ClientMode::ALL {
            let report = loadgen::run(&LoadGenConfig {
                addr: event_addr.clone(),
                connections: conns,
                ..loadcfg(mode)
            })
            .expect(mode.name());
            assert_eq!(
                report.answered + report.overloaded,
                requests as u64,
                "every request answered (event-loop {})",
                mode.name()
            );
            row(&mut table2, &format!("event-loop/{}", mode.name()), &report);
            passes.push((format!("event-loop/{}", mode.name()), report));
        }
        // Batched pass on the event loop — the default Linux backend,
        // and the one the batch throughput floor gates in CI.
        let event_batch = loadgen::run(&LoadGenConfig {
            addr: event_addr.clone(),
            connections: conns,
            mode: ClientMode::Batch,
            batch_size: 16,
            ..loadcfg(ClientMode::Batch)
        })
        .expect("event-loop batch pass");
        assert_eq!(
            event_batch.answered + event_batch.overloaded,
            requests as u64,
            "every batched example answered (event loop)"
        );
        row(&mut table2, "event-loop/batch", &event_batch);
        passes.push(("event-loop/batch".to_string(), event_batch));
        event_server.shutdown();
        // Thread backend at the same connection count, v2-binary only:
        // the apples-to-apples throughput ratio.
        let threads_server = TcpServer::serve_models(
            &srv_cfg,
            vec![
                ("default".to_string(), attentive_snapshot.into()),
                ("digits".to_string(), ensemble_snapshot.into()),
            ],
        )
        .expect("bind loopback (threads wide)");
        let threads_wide = loadgen::run(&LoadGenConfig {
            addr: threads_server.local_addr().to_string(),
            connections: conns,
            ..loadcfg(ClientMode::V2Binary)
        })
        .expect("threads wide pass");
        threads_server.shutdown();
        row(&mut table2, "threads/v2-binary-wide", &threads_wide);
        let event_wide = passes
            .iter()
            .find(|(name, _)| name == "event-loop/v2-binary")
            .map(|(_, r)| r.req_per_s())
            .unwrap_or(0.0);
        if threads_wide.req_per_s() > 0.0 {
            let ratio = event_wide / threads_wide.req_per_s();
            println!(
                "backends at {conns} connections: event-loop {event_wide:.0} req/s vs \
                 threads {:.0} req/s ({ratio:.2}x) on v2-binary",
                threads_wide.req_per_s(),
            );
            event_ratio = Some(ratio);
        }
        passes.push(("threads-v2-binary-wide".to_string(), threads_wide));
        println!("{}", table2.render());
    }

    let out = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut report_json = loadgen::report_to_json(requests, &passes);
    if let attentive::util::json::Json::Obj(pairs) = &mut report_json {
        if let Some(ratio) = event_ratio {
            pairs.push((
                "ratio_event_loop_vs_threads_v2_binary".to_string(),
                attentive::util::json::Json::Num(ratio),
            ));
        }
    }
    to_json_file(&report_json, std::path::Path::new(&out)).expect("write bench json");
    println!("machine-readable report written to {out}");
}
