//! Serving throughput over loopback TCP: attentive early-exit vs full
//! evaluation on identical traffic.
//!
//! Spawns the JSON-lines front-end on an ephemeral port, drives it with
//! the load-generator client (mixed clean/noisy digit traffic, pipelined
//! connections), hot-reloads the same weights under the Full boundary via
//! the control channel, and replays the identical request stream —
//! reporting req/s and features-touched percentiles for both. The gap is
//! the paper's focus-of-attention, measured at the wire.
//!
//! `cargo bench --bench serve_throughput` (BENCH_QUICK=1 for CI scale)

use attentive::config::ServerConfig;
use attentive::coordinator::service::ModelSnapshot;
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::Table;
use attentive::server::loadgen::{self, Client, LoadGenConfig, LoadReport};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: f64 = 784.0;

fn train_snapshot(count: usize) -> ModelSnapshot {
    let ds = SynthDigits::new(7).generate_classes(count, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).expect("task");
    let mut learner = attentive_pegasos(task.dim(), 1e-4, 0.1);
    Trainer::new(TrainerConfig { epochs: 3, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    ModelSnapshot::from_trained(
        &mut learner,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        CoordinatePolicy::Permuted,
    )
}

fn row(table: &mut Table, name: &str, r: &LoadReport) {
    let early_rate = if r.features.is_empty() {
        0.0
    } else {
        r.features.iter().filter(|&&f| (f as f64) < DIM).count() as f64 / r.features.len() as f64
    };
    table.row(&[
        name.into(),
        format!("{:.0}", r.req_per_s()),
        format!("{:.1}", r.avg_features()),
        format!("{}", r.feature_percentile(0.50)),
        format!("{}", r.feature_percentile(0.90)),
        format!("{}", r.feature_percentile(0.99)),
        format!("{:.3}", early_rate),
        format!("{}", r.overloaded),
    ]);
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (train_count, requests) = if quick { (2_000, 2_000) } else { (6_000, 10_000) };

    let attentive_snapshot = train_snapshot(train_count);
    let mut full_snapshot = attentive_snapshot.clone();
    full_snapshot.boundary = AnyBoundary::Full;

    let srv_cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        max_batch: 16,
        queue: 4096,
        ..Default::default()
    };
    let server = TcpServer::serve(&srv_cfg, attentive_snapshot).expect("bind loopback");
    let addr = server.local_addr().to_string();
    println!(
        "loopback serving bench on {addr}: {requests} requests/pass, 8 connections, pipeline 16"
    );

    let loadcfg = LoadGenConfig {
        addr: addr.clone(),
        connections: 8,
        requests,
        pipeline: 16,
        hard_fraction: 0.5,
        seed: 11, // same seed both passes -> identical traffic
    };

    let mut table = Table::new(&[
        "serving",
        "req/s",
        "avg feats",
        "p50",
        "p90",
        "p99",
        "early-exit",
        "shed",
    ]);

    let att = loadgen::run(&loadcfg).expect("attentive pass");
    assert_eq!(att.answered + att.overloaded, requests as u64, "every request answered");
    row(&mut table, "attentive(δ=0.1)", &att);

    let mut control = Client::connect(&addr).expect("control channel");
    control.reload(&full_snapshot).expect("hot reload to full evaluation");
    let full = loadgen::run(&loadcfg).expect("full pass");
    assert_eq!(full.answered + full.overloaded, requests as u64, "every request answered");
    row(&mut table, "full", &full);

    println!("{}", table.render());
    let stats = control.stats().expect("stats");
    drop(control);
    server.shutdown();

    println!(
        "server totals: {} served, {} batches, early-exit rate {:.3}, {} reload(s)",
        stats.served, stats.batches, stats.early_exit_rate, stats.reloads
    );
    if att.avg_features() > 0.0 {
        println!(
            "features/request: attentive {:.1} vs full {:.1} ({:.1}x attention saving); \
             wire throughput {:.0} vs {:.0} req/s",
            att.avg_features(),
            full.avg_features(),
            full.avg_features() / att.avg_features(),
            att.req_per_s(),
            full.req_per_s(),
        );
    }
}
